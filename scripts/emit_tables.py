"""Render EXPERIMENTS.md-ready markdown tables from artifacts/dryrun."""
import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"

rows = []
for fn in sorted(os.listdir(DIR)):
    if fn.endswith(".json"):
        with open(os.path.join(DIR, fn)) as f:
            rows.append(json.load(f))

base = [r for r in rows if not r.get("tag")]
tagged = [r for r in rows if r.get("tag")]

print("### Dry-run + roofline — baselines\n")
print("| arch | shape | mesh | compile_s | args GiB/dev | t_comp s | t_mem s"
      " | t_coll s | bottleneck | useful | roofline frac |")
print("|---|---|---|---|---|---|---|---|---|---|---|")
for r in base:
    if r["status"] != "ok":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR: "
              f"{r.get('error','')[:40]} |||||||")
        continue
    rf = r["roofline"]
    mem = r["memory"]["argument_bytes"] / 2**30
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
          f"{r.get('compile_s', 0):.0f} | {mem:.2f} | "
          f"{rf['t_compute']:.3g} | {rf['t_memory']:.3g} | "
          f"{rf['t_collective']:.3g} | {rf['bottleneck']} | "
          f"{rf['useful_ratio']:.2f} | {100*rf['roofline_fraction']:.2f}% |")

n_ok = sum(r["status"] == "ok" for r in base)
print(f"\n{n_ok}/{len(base)} baseline cells ok\n")

print("### Perf variants (tagged)\n")
print("| arch | shape | mesh | tag | t_comp | t_mem | t_coll | bound s | frac |")
print("|---|---|---|---|---|---|---|---|---|")
for r in tagged:
    if r["status"] != "ok":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tag']} | "
              f"ERROR {r.get('error','')[:40]} |||||")
        continue
    rf = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tag']} | "
          f"{rf['t_compute']:.3g} | {rf['t_memory']:.3g} | "
          f"{rf['t_collective']:.3g} | {rf['step_time_bound_s']:.3g} | "
          f"{100*rf['roofline_fraction']:.2f}% |")
