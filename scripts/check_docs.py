"""Docs gate (CI): DESIGN.md/README.md exist and every `DESIGN.md §<n>` /
`EXPERIMENTS.md §<name>` cross-reference in the tree resolves to a real
section header. Exits 1 listing any dangling reference.

Run: python scripts/check_docs.py  (from the repo root; no deps)
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
REQUIRED = ("DESIGN.md", "README.md", "EXPERIMENTS.md")

# sections that must exist even if nothing currently cross-references
# them — the documented API surface of record. New subsystems register
# their section here (e.g. §10: streaming ingestion / CSR cache).
REQUIRED_SECTIONS = {
    "DESIGN.md": {"1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11",
                  "12", "13", "14", "15", "16"},
    "EXPERIMENTS.md": {"Dry-run", "Roofline", "Perf", "Memory", "Resume",
                       "Queries"},
}

# README headings other docs/source point operators at by name — same
# contract as REQUIRED_SECTIONS, but README sections are titled, not
# §-numbered.
REQUIRED_HEADINGS = {
    "README.md": {"Running across hosts"},
}


def section_headers(path: str) -> set[str]:
    """§-tokens appearing in markdown headers of ``path``."""
    out: set[str] = set()
    with open(path, encoding="utf-8") as f:
        for line in f:
            if line.startswith("#"):
                out.update(re.findall(r"§([\w-]+)", line))
    return out


def iter_source_files():
    for d in SCAN_DIRS:
        for dirpath, _dirnames, filenames in os.walk(os.path.join(ROOT, d)):
            if "__pycache__" in dirpath:
                continue
            for fn in filenames:
                if fn.endswith((".py", ".md", ".yml", ".yaml")):
                    yield os.path.join(dirpath, fn)


def main() -> int:
    errors = []
    for doc in REQUIRED:
        if not os.path.exists(os.path.join(ROOT, doc)):
            errors.append(f"missing required doc: {doc}")
    if errors:
        print("\n".join(errors))
        return 1

    sections = {doc: section_headers(os.path.join(ROOT, doc))
                for doc in ("DESIGN.md", "EXPERIMENTS.md")}
    for doc, required in REQUIRED_SECTIONS.items():
        for miss in sorted(required - sections[doc]):
            errors.append(f"{doc}: missing required section §{miss}")
    for doc, headings in REQUIRED_HEADINGS.items():
        with open(os.path.join(ROOT, doc), encoding="utf-8") as f:
            header_lines = [ln for ln in f if ln.startswith("#")]
        for h in sorted(headings):
            if not any(h in ln for ln in header_lines):
                errors.append(f"{doc}: missing required heading \"{h}\"")
    n_refs = 0
    for path in iter_source_files():
        rel = os.path.relpath(path, ROOT)
        if os.path.samefile(path, os.path.abspath(__file__)):
            continue  # this file's §-strings are patterns, not references
        with open(path, encoding="utf-8") as f:
            text = f.read()
        mentions = [(m.start(), m.group(0))
                    for m in re.finditer(r"(?:DESIGN|EXPERIMENTS)\.md", text)]
        # attribute each §-token to the nearest preceding doc mention
        # within a window — survives line wraps ("...EXPERIMENTS.md\n
        # §Dry-run") and ranges ("DESIGN.md §3/§4"); a token with no
        # nearby mention (e.g. a bare "§Perf iteration" note) is skipped
        for m in re.finditer(r"§([\w-]+)", text):
            near = [d for p, d in mentions if 0 <= m.start() - p <= 120]
            if not near:
                continue
            doc, ref = near[-1], m.group(1)
            n_refs += 1
            if ref not in sections[doc]:
                lineno = text.count("\n", 0, m.start()) + 1
                errors.append(f"{rel}:{lineno}: dangling {doc} §{ref}")
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} dangling doc reference(s)")
        return 1
    print(f"docs ok: {', '.join(REQUIRED)} present; "
          f"{n_refs} §-references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
