"""Collective breakdown for one cell: group HLO collective ops by kind+shape
to find the dominant traffic source (hillclimb profiling)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections
import re
import sys

from repro.configs import get_config
from repro.launch.lowering import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.dist.sharding import make_rules
from repro.launch.costs import _COLL_RE, _shape_bytes

def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variant = dict(kv.split("=") for kv in sys.argv[3:])
    cfg = get_config(arch)
    mesh = make_production_mesh()
    rules = None
    if variant:
        from repro.launch.dryrun import apply_variants
        cfg, rules = apply_variants(cfg, mesh, shape, variant)
    cell = build_cell(cfg, shape, mesh, rules=rules)
    compiled = lower_cell(cell).compile()
    hlo = compiled.as_text()
    agg = collections.Counter()
    cnt = collections.Counter()
    for m in _COLL_RE.finditer(hlo):
        shapes, op = m.group(1), m.group(2)
        key = f"{op} {shapes[:70]}"
        agg[key] += _shape_bytes(shapes)
        cnt[key] += 1
    total = sum(agg.values())
    print(f"total collective operand bytes/dev (unweighted): {total/2**30:.2f} GiB")
    for key, b in agg.most_common(12):
        print(f"  {b/2**30:7.2f} GiB  x{cnt[key]:<4} {key}")
    ca = compiled.cost_analysis()
    print("flops/dev:", ca.get("flops"), "bytes/dev:", ca.get("bytes accessed"))

if __name__ == "__main__":
    main()
