"""Measure the scoring-only cost of the merge-gain oracle at the per-device
web-uk-05 shapes (§Perf iteration C3): the dry-run runs the pure-jnp oracle
(Pallas interpret mode is a host callback, invisible to cost_analysis), so
its dense [G,C,C,U] materializations inflate the memory term. This script
quantifies that inflation and the Pallas kernel's streaming-bytes replacement.
"""
import sys

import jax
import jax.numpy as jnp

from repro.kernels import ref

G, C, U = (int(x) for x in (sys.argv[1:4] or (2407, 64, 128)))

args = [
    jnp.zeros((G, C, U), jnp.float32),   # m
    jnp.ones((G, C), jnp.float32),       # n
    jnp.zeros((G, C), jnp.float32),      # s
    jnp.ones((G, C), jnp.float32),       # t
    jnp.ones((G, U), jnp.float32),       # n_u
    jnp.zeros((G, C), jnp.int32),        # cidx
    jnp.zeros((G, C, C), jnp.float32),   # w
]
lowered = jax.jit(ref.merge_gain_ref).lower(*args, jnp.float32(60.0),
                                            jnp.float32(20.0))
ca = lowered.compile().cost_analysis()
oracle_bytes = float(ca.get("bytes accessed", 0.0))
oracle_flops = float(ca.get("flops", 0.0))

# Pallas kernel HBM traffic: every operand read once, outputs written once
# (the [C,U]/[C,C] working set lives in VMEM for the whole group program)
operand = (G * C * U + G * C * 4 + G * U + G * C * C) * 4.0
outputs = 2 * G * C * C * 4.0
kernel_bytes = operand + outputs

print(f"shapes G={G} C={C} U={U}")
print(f"oracle  bytes_accessed: {oracle_bytes/2**30:8.2f} GiB  "
      f"flops {oracle_flops:.3e}")
print(f"pallas  streaming bytes: {kernel_bytes/2**30:8.2f} GiB")
print(f"inflation: {oracle_bytes/kernel_bytes:.1f}x")
