"""Bench regression gate (CI): fig6 wall-clock vs the committed baseline.

Compares the `fig6` rows of `artifacts/bench/fig6_scalability.json`
against `benchmarks/baselines/fig6_baseline.json` by (dataset, scale) and
exits 1 if any scale regressed by more than --tolerance (default 25%)
*and* by more than --min-delta-s (absolute noise floor — sub-second CI
timings jitter far more than 25%). `--update` rewrites the baseline from
the current artifact instead (how the baseline was seeded).

Run after the benchmark:  python scripts/check_bench.py
"""

from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "artifacts", "bench", "fig6_scalability.json")
BASELINE = os.path.join(ROOT, "benchmarks", "baselines",
                        "fig6_baseline.json")


def _rows(path: str) -> dict[tuple, dict]:
    with open(path) as f:
        rows = json.load(f)
    return {(r["dataset"], r["scale"]): r
            for r in rows if r.get("bench") == "fig6"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default=ARTIFACT)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative wall-clock regression budget per scale")
    ap.add_argument("--min-delta-s", type=float, default=0.5,
                    help="ignore regressions smaller than this in absolute "
                         "seconds (timer noise on shared CI runners)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current artifact")
    args = ap.parse_args(argv)

    if not os.path.exists(args.artifact):
        print(f"missing benchmark artifact: {args.artifact} "
              f"(run benchmarks.fig6_scalability first)")
        return 1
    cur = _rows(args.artifact)
    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        keep = [{k: r[k] for k in
                 ("bench", "dataset", "scale", "V", "E", "T", "wall_s")}
                for r in cur.values()]
        with open(args.baseline, "w") as f:
            json.dump(keep, f, indent=1)
        print(f"baseline updated: {args.baseline} ({len(keep)} scales)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"missing baseline: {args.baseline} "
              f"(seed it with --update)")
        return 1
    base = _rows(args.baseline)
    failures, checked = [], 0
    for key, b in sorted(base.items()):
        c = cur.get(key)
        if c is None:
            print(f"warn: baseline scale {key} not in current artifact; "
                  f"skipped")
            continue
        checked += 1
        ratio = c["wall_s"] / max(b["wall_s"], 1e-9)
        delta = c["wall_s"] - b["wall_s"]
        verdict = "ok"
        if ratio > 1.0 + args.tolerance and delta > args.min_delta_s:
            verdict = "REGRESSION"
            failures.append(key)
        print(f"{key[0]} @ scale {key[1]}: {b['wall_s']:.3f}s -> "
              f"{c['wall_s']:.3f}s ({ratio:.2f}x) {verdict}")
    if not checked:
        print("no overlapping (dataset, scale) rows between baseline and "
              "artifact")
        return 1
    if failures:
        print(f"\n{len(failures)} scale(s) regressed beyond "
              f"{args.tolerance:.0%} (+{args.min_delta_s}s floor)")
        return 1
    print(f"\nbench gate ok: {checked} scale(s) within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
