"""Bench regression gate (CI): benchmark artifacts vs committed baselines.

Three gated benches, selected with ``--bench``:

  * ``fig6`` (default) — `artifacts/bench/fig6_scalability.json` vs
    `benchmarks/baselines/fig6_baseline.json`, keyed (dataset, scale),
    metric wall_s (higher is worse). Fails a scale that regressed by more
    than --tolerance *and* by more than --min-delta-s (absolute noise
    floor — sub-second CI timings jitter far more than 25%).
  * ``querybench`` — `artifacts/bench/querybench.json` vs
    `benchmarks/baselines/querybench_baseline.json`, keyed
    (engine, batch), metric qps (lower is worse). Throughput on shared
    runners jitters, so the CI invocation passes a wide --tolerance.
  * ``multihost`` — `artifacts/bench/multihost.json` vs
    `benchmarks/baselines/multihost_baseline.json`, keyed (leg,), metric
    wall_s (higher is worse): the per-leg wall clocks of
    ``tests/multihost_check.py`` (golden / multihost / resume), so a
    cross-process slowdown fails the gate like any other regression.

``--update`` rewrites the selected baseline from the current artifact
instead (how both baselines were seeded).

Run after the benchmark:  python scripts/check_bench.py [--bench querybench]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART_DIR = os.path.join(ROOT, "artifacts", "bench")
BASE_DIR = os.path.join(ROOT, "benchmarks", "baselines")

BENCHES = {
    "fig6": dict(
        artifact=os.path.join(ART_DIR, "fig6_scalability.json"),
        baseline=os.path.join(BASE_DIR, "fig6_baseline.json"),
        key=("dataset", "scale"),
        metric="wall_s",
        higher_is_worse=True,
        keep=("bench", "dataset", "scale", "V", "E", "T", "wall_s"),
    ),
    "querybench": dict(
        artifact=os.path.join(ART_DIR, "querybench.json"),
        baseline=os.path.join(BASE_DIR, "querybench_baseline.json"),
        key=("engine", "batch"),
        metric="qps",
        higher_is_worse=False,
        keep=("bench", "engine", "batch", "query", "requests", "qps"),
    ),
    "multihost": dict(
        artifact=os.path.join(ART_DIR, "multihost.json"),
        baseline=os.path.join(BASE_DIR, "multihost_baseline.json"),
        key=("leg",),
        metric="wall_s",
        higher_is_worse=True,
        keep=("bench", "leg", "processes", "devices_per_process", "wall_s"),
    ),
}


def _rows(path: str, spec: dict, bench: str) -> dict[tuple, dict]:
    with open(path) as f:
        rows = json.load(f)
    return {tuple(r[k] for k in spec["key"]): r
            for r in rows if r.get("bench") == bench}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="fig6", choices=sorted(BENCHES))
    ap.add_argument("--artifact", default=None)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression budget per row (wall-clock "
                         "growth for fig6, QPS loss for querybench)")
    ap.add_argument("--min-delta-s", type=float, default=0.5,
                    help="fig6 only: ignore regressions smaller than this "
                         "in absolute seconds (timer noise on shared CI "
                         "runners)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current artifact")
    args = ap.parse_args(argv)

    spec = BENCHES[args.bench]
    artifact = args.artifact or spec["artifact"]
    baseline = args.baseline or spec["baseline"]
    metric = spec["metric"]

    if not os.path.exists(artifact):
        print(f"missing benchmark artifact: {artifact} "
              f"(run the {args.bench} benchmark first)")
        return 1
    cur = _rows(artifact, spec, args.bench)
    if args.update:
        os.makedirs(os.path.dirname(baseline), exist_ok=True)
        keep = [{k: r[k] for k in spec["keep"] if k in r}
                for r in cur.values()]
        with open(baseline, "w") as f:
            json.dump(keep, f, indent=1)
        print(f"baseline updated: {baseline} ({len(keep)} rows)")
        return 0

    if not os.path.exists(baseline):
        print(f"missing baseline: {baseline} (seed it with --update)")
        return 1
    base = _rows(baseline, spec, args.bench)
    failures, checked = [], 0
    for key, b in sorted(base.items()):
        c = cur.get(key)
        if c is None:
            print(f"warn: baseline row {key} not in current artifact; "
                  f"skipped")
            continue
        checked += 1
        ratio = c[metric] / max(b[metric], 1e-9)
        verdict = "ok"
        if spec["higher_is_worse"]:
            delta = c[metric] - b[metric]
            if ratio > 1.0 + args.tolerance and delta > args.min_delta_s:
                verdict = "REGRESSION"
        elif ratio < 1.0 - args.tolerance:
            verdict = "REGRESSION"
        if verdict != "ok":
            failures.append(key)
        label = " @ ".join(str(k) for k in key)
        print(f"{label}: {metric} {b[metric]:.3f} -> {c[metric]:.3f} "
              f"({ratio:.2f}x) {verdict}")
    if not checked:
        print(f"no overlapping {spec['key']} rows between baseline and "
              f"artifact")
        return 1
    if failures:
        print(f"\n{len(failures)} row(s) regressed beyond "
              f"{args.tolerance:.0%}")
        return 1
    print(f"\nbench gate ok: {checked} row(s) within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
