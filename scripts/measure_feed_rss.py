"""Measure the host-RSS cost of getting an edge list onto the mesh.

Compares, in separate child processes (fresh jax runtimes, same fixture):

  * ``densify`` — the pre-feed data path: densify the mmap'd cache
    columns through ``make_graph`` (int64 canonicalization) and build
    full-length padded host copies, the way ``pad_and_shard_edges``
    worked before `repro.graphs.feed`;
  * ``feed``    — the out-of-core path: ``shard_edges_from_cache`` slices
    the mmap straight into per-device shards (host staging = one shard).

Each child reports two deltas over the data path: **resident** growth
(current RSS after − before, the steady-state cost of what the path
leaves allocated) and **peak** growth (ru_maxrss after − before, the
transient sort/unique scratch — visible once it exceeds the jax-init
high-water mark). The parent writes ``artifacts/memory/feed_rss.json``
(the EXPERIMENTS.md §Memory numbers; uploaded by the CI ``ingest`` job)
and prints a table.

Run:  PYTHONPATH=src python scripts/measure_feed_rss.py data/rmat_1m.txt.gz
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def _current_rss_mb() -> float:
    """RSS *right now* (not the lifetime peak): the baseline must not
    already contain the jax-init high-water mark, or any data-path cost
    below that mark measures as zero. Linux-only (/proc); falls back to
    the peak elsewhere (deltas then read as lower bounds)."""
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE") \
                / (1 << 20)
    except (OSError, ValueError, IndexError):
        from repro.launch.summarize import peak_rss_mb

        return peak_rss_mb() or 0.0


def child(mode: str, path: str, devices: int) -> None:
    import jax
    import numpy as np

    from repro.graphs import load_graph
    from repro.launch.mesh import make_host_mesh

    from repro.launch.summarize import peak_rss_mb

    assert jax.device_count() == devices
    mesh = make_host_mesh((devices,), ("data",))
    g = load_graph(path)
    assert g.cache_dir is not None, f"{path}: no CSR cache"
    rec = {"mode": mode, "V": g.num_nodes, "E": g.num_edges,
           "devices": devices, "baseline_mb": _current_rss_mb(),
           "baseline_peak_mb": peak_rss_mb()}

    if mode == "densify":
        # the historical path: canonicalize on host, build full padded
        # copies, commit to the default device and let jit reshard
        import jax.numpy as jnp

        from repro.core.types import make_graph

        graph, _ = make_graph(np.asarray(g.src), np.asarray(g.dst),
                              g.num_nodes)
        e = graph.num_edges
        pad = (-e) % devices
        src_p = np.concatenate([np.asarray(graph.src, np.int32),
                                np.full(pad, -1, np.int32)])
        dst_p = np.concatenate([np.asarray(graph.dst, np.int32),
                                np.full(pad, -1, np.int32)])
        src_g, dst_g = jnp.asarray(src_p), jnp.asarray(dst_p)
    else:
        from repro.graphs.feed import shard_edges_from_cache

        shards = shard_edges_from_cache(g.cache_dir, mesh)
        src_g, dst_g = shards.src, shards.dst
        rec["feed"] = shards.stats.asdict()

    src_g.block_until_ready(), dst_g.block_until_ready()
    # two deltas, two regimes: resident growth (current − current) is the
    # steady-state cost of the arrays the path leaves behind, and survives
    # even when everything stays below the jax-init transient high-water
    # mark; peak growth (ru_maxrss − ru_maxrss) is the transient scratch
    # (sort/unique) and is only visible once it exceeds that mark
    rec["after_mb"] = _current_rss_mb()
    rec["peak_mb"] = peak_rss_mb()
    rec["delta_resident_mb"] = rec["after_mb"] - rec["baseline_mb"]
    rec["delta_peak_mb"] = max(
        (rec["peak_mb"] or 0.0) - (rec["baseline_peak_mb"] or 0.0), 0.0)
    print(json.dumps(rec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="SNAP edge-list file (cache built on "
                                 "first use)")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--child", choices=("densify", "feed"), default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default="artifacts/memory/feed_rss.json")
    args = ap.parse_args()
    if args.child:
        child(args.child, args.path, args.devices)
        return

    # warm the cache once so neither child pays for ingestion
    from repro.graphs import load_graph

    load_graph(args.path)

    rows = []
    for mode in ("densify", "feed"):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), args.path,
             "--devices", str(args.devices), "--child", mode],
            capture_output=True, text=True, env=env, check=True)
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    dens, feed = rows
    print(f"|E| = {dens['E']:,}  devices = {dens['devices']}")
    for r in rows:
        print(f"  {r['mode']:8s} resident {r['baseline_mb']:7.1f} → "
              f"{r['after_mb']:7.1f} MB (Δ {r['delta_resident_mb']:+7.1f}), "
              f"peak Δ {r['delta_peak_mb']:+7.1f} MB")
    f = feed.get("feed", {})
    if f:
        print(f"  feed staging high-water: {f['peak_staging_bytes']:,} B "
              f"(= one shard of {f['shard_rows']:,} rows; "
              f"full |E| column would be {4 * dens['E']:,} B)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
