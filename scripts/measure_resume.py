"""Measure checkpoint/resume overhead (EXPERIMENTS.md §Resume).

    PYTHONPATH=src python scripts/measure_resume.py \
        --dataset dblp --scale 0.2 --T 12 --driver-chunk 1

Times three things against one workload:

  1. a plain run (no checkpointing) — the baseline wall;
  2. the same run saving at every chunk boundary — per-save driver stall
     (the synchronous device→host snapshot), background write wall and
     committed bytes from ``CheckpointManager.save_stats``, and the total
     wall delta;
  3. a restore + resume from the *first* committed step — restore latency
     (fingerprint check + leaf loads + device_put) and the resumed wall.

Prints one JSON record; ``--distributed`` measures the edge-sharded
backend over every local device instead of the single-device path.
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="dblp")
    ap.add_argument("--edge-list", default=None)
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=12)
    ap.add_argument("--driver-chunk", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.core import SummaryConfig
    from repro.core.engine import EngineCheckpointer, SummaryEngine
    from repro.graphs import load_graph
    from repro.runtime import CheckpointManager

    g = load_graph(args.edge_list or args.dataset, scale=args.scale,
                   seed=args.seed)
    src, dst, v = np.asarray(g.src), np.asarray(g.dst), g.num_nodes
    cfg = SummaryConfig(T=args.T, k_frac=args.k_frac, seed=args.seed,
                        driver_chunk=args.driver_chunk)

    if args.distributed:
        from repro.core.distributed import make_distributed_backend
        from repro.core.types import make_graph
        from repro.graphs.feed import shard_edges, shard_edges_from_cache
        from repro.runtime import make_mesh_from_plan, plan_mesh

        mesh = make_mesh_from_plan(
            plan_mesh(jax.device_count(), global_batch=1, want_model=1))
        if g.cache_dir is not None:
            shards = shard_edges_from_cache(g.cache_dir, mesh)
        else:
            graph, _ = make_graph(src, dst, v)
            shards = shard_edges(np.asarray(graph.src),
                                 np.asarray(graph.dst), mesh)
        backend = make_distributed_backend(
            mesh, cfg, v, shards.num_edges, grouping="compact",
            capacity_factor=32.0, lean_sort=True).bind(shards.src,
                                                       shards.dst)
        mode = f"distributed{dict(mesh.shape)}"
    else:
        from repro.core.engine import LocalBackend

        backend = LocalBackend(src, dst, v, cfg)
        mode = "local"

    def run(**kw):
        t0 = time.perf_counter()
        out = SummaryEngine(backend).run(collect_history=False, **kw)
        return out, time.perf_counter() - t0

    _, warm = run()  # compile
    _, wall_plain = run()

    d = tempfile.mkdtemp(prefix="measure_resume_")
    try:
        ck = EngineCheckpointer(manager=CheckpointManager(d, keep=1000),
                                every=1)
        full, wall_ckpt = run(checkpointer=ck)
        stats = sorted(ck.manager.save_stats.items())
        snaps = [s["snapshot_wall_s"] for _, s in stats
                 if s["snapshot_wall_s"] is not None]
        writes = [s["write_wall_s"] for _, s in stats
                  if s["write_wall_s"] is not None]
        byts = [s["bytes"] for _, s in stats if s["bytes"]]

        steps = ck.manager.all_steps()
        for s in steps[1:]:
            shutil.rmtree(f"{d}/step_{s:010d}")
        ck2 = EngineCheckpointer(manager=CheckpointManager(d, keep=1000),
                                 every=1)
        t0 = time.perf_counter()
        restored = ck2.restore(backend)
        restore_wall = time.perf_counter() - t0
        res, wall_resumed = run(checkpointer=ck2, resume=True)
        assert restored is not None and res.resumed_from == steps[0]
        assert float(res.finalize["stats" if args.distributed else "after"]
                     ["size_bits"]) == \
            float(full.finalize["stats" if args.distributed else "after"]
                  ["size_bits"])
    finally:
        shutil.rmtree(d, ignore_errors=True)

    print(json.dumps({
        "mode": mode, "V": v, "E": int(len(src)),
        "dataset": args.edge_list or args.dataset,
        "rounds": full.iterations_run,
        "saves": full.checkpoint_saves,
        "wall_plain_s": wall_plain,
        "wall_checkpointed_s": wall_ckpt,
        "overhead_frac": wall_ckpt / wall_plain - 1.0,
        "snapshot_mean_ms": 1e3 * float(np.mean(snaps)),
        "snapshot_total_ms": 1e3 * float(np.sum(snaps)),
        "write_mean_ms": 1e3 * float(np.mean(writes)),
        "checkpoint_bytes": int(np.max(byts)) if byts else 0,
        "restore_wall_ms": 1e3 * restore_wall,
        "wall_resumed_s": wall_resumed,
        "resumed_from_step": steps[0],
    }, indent=1))


if __name__ == "__main__":
    main()
