"""Dev probe (superseded by `python -m repro.launch.dryrun` for real runs):
lower+compile one full-size cell and print raw memory/cost analysis.
Run: PYTHONPATH=src python scripts/probe_dryrun.py <arch> <shape> [--multi-pod]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
import time

import jax

from repro.configs import get_config, SHAPES
from repro.dist.sharding import make_rules
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, input_specs
from repro.optim import adamw_init


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma_7b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    multi = "--multi-pod" in sys.argv
    cfg = get_config(arch)
    sp = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi)
    mode = "train" if sp.kind == "train" else "serve"
    rules = make_rules(mesh, mode)
    model = build_model(cfg)

    t0 = time.time()
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.axes()
    p_shard = jax.tree.map(
        lambda s, a: rules.sharding(a, s.shape), params_s, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    batch = input_specs(cfg, shape)
    print(f"eval_shape: {time.time()-t0:.1f}s; params leaves={len(jax.tree.leaves(params_s))}")

    if sp.kind == "train":
        opt_s = jax.eval_shape(adamw_init, params_s)
        o_shard = type(opt_s)(
            step=rules.sharding((), ()),
            mu=jax.tree.map(lambda s, a: rules.sharding(a, s.shape), opt_s.mu, axes,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
            nu=jax.tree.map(lambda s, a: rules.sharding(a, s.shape), opt_s.nu, axes,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
        )
        b_shard = jax.tree.map(
            lambda s: rules.sharding(("batch", "seq") if len(s.shape) == 2 else ("batch", "seq", None), s.shape),
            batch, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

        def step(params, opt, b):
            return model.train_step(params, opt, b, rules)

        t0 = time.time()
        lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard)).lower(params_s, opt_s, batch)
        t_lower = time.time() - t0
        print(f"lower: {t_lower:.1f}s")
    else:
        if sp.kind == "decode":
            cache_axes = model.cache_axes()
            c_shard = jax.tree.map(lambda a: None, cache_axes, is_leaf=lambda x: isinstance(x, tuple))
            batch_shardings = {
                "token": rules.sharding(("batch",), (sp.global_batch,)),
                "pos": rules.sharding((), ()),
                "cache": jax.tree.map(
                    lambda s, a: rules.sharding(a, s.shape), batch["cache"], cache_axes,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
            }

            def step(params, b):
                return model.serve_step(params, b, rules)
        else:  # prefill
            batch_shardings = jax.tree.map(
                lambda s: rules.sharding(("batch", "seq") if len(s.shape) == 2 else ("batch", "seq", None), s.shape),
                batch, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

            def step(params, b):
                return model.prefill_step(params, b, rules)

        t0 = time.time()
        lowered = jax.jit(step, in_shardings=(p_shard, batch_shardings)).lower(params_s, batch)
        t_lower = time.time() - t0
        print(f"lower: {t_lower:.1f}s")

    t0 = time.time()
    compiled = lowered.compile()
    t_comp = time.time() - t0
    print(f"compile: {t_comp:.1f}s")
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    print("memory:", ma)
    print("flops:", ca.get("flops"), "bytes:", ca.get("bytes accessed"))


if __name__ == "__main__":
    main()
