"""Recompute the roofline block of dry-run artifacts from their stored
cost/collective inputs (no recompilation) — used when the analytic
correction model changes (e.g. the remat="dots" multiplier)."""
import json
import sys

from repro.configs import SHAPES, get_config
from repro.launch import costs as rcosts

for path in sys.argv[1:]:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok" or "cost" not in rec:
        print(f"skip {path}")
        continue
    cfg = get_config(rec["arch"])
    rv = rec.get("variants", {}).get("remat")
    remat = not rv or rv == "full"
    rec["roofline"] = rcosts.roofline(
        hlo_flops_per_dev=rec["cost"]["flops"],
        hlo_bytes_per_dev=rec["cost"]["bytes_accessed"],
        coll_bytes_per_dev=rec["collectives"]["total"],
        cfg=cfg, sp=SHAPES[rec["shape"]], n_chips=rec["n_devices"],
        remat=remat,
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rf = rec["roofline"]
    print(f"{path}: comp={rf['t_compute']:.3f} mem={rf['t_memory']:.3f} "
          f"coll={rf['t_collective']:.3f} frac={rf['roofline_fraction']:.4f}")
