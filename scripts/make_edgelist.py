"""Emit a deterministic synthetic graph as a SNAP-format edge-list file.

Fixture/CI writer for the streaming loader (`repro.graphs.io`): registry
stand-ins or explicit R-MAT sizes, written as `.txt`/`.csv` (gzip when the
path ends in `.gz`), with optional loader-hostile noise — shuffled order,
flipped directions, duplicates, self-loops, 1-indexing.

    PYTHONPATH=src python scripts/make_edgelist.py --dataset dblp \
        --scale 1.0 --shuffle --dup-frac 0.05 --out data/dblp.txt.gz

    PYTHONPATH=src python scripts/make_edgelist.py --v 262144 --e 1200000 \
        --shuffle --out data/rmat_1m.txt.gz
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.graphs import DATASETS, generate, write_edge_list  # noqa: E402
from repro.graphs.synthetic import rmat  # noqa: E402


def main(argv=None) -> str:
    ap = argparse.ArgumentParser(description=__doc__)
    src_grp = ap.add_mutually_exclusive_group()
    src_grp.add_argument("--dataset", choices=sorted(DATASETS),
                         help="registry stand-in (with --scale)")
    src_grp.add_argument("--v", type=int, help="explicit R-MAT |V| "
                         "(rounded up to a power of two; use with --e)")
    ap.add_argument("--e", type=int, default=None, help="R-MAT edge target")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", required=True, metavar="PATH",
                    help=".txt/.csv, gzip'd when ending in .gz")
    ap.add_argument("--shuffle", action="store_true",
                    help="permute edge order and flip random directions")
    ap.add_argument("--dup-frac", type=float, default=0.0)
    ap.add_argument("--self-loops", type=int, default=0)
    ap.add_argument("--one-indexed", action="store_true")
    ap.add_argument("--no-header", action="store_true",
                    help="omit the '# Nodes: V Edges: E' SNAP header")
    args = ap.parse_args(argv)

    if args.v is not None:
        if args.e is None:
            ap.error("--v requires --e")
        bits = int(np.ceil(np.log2(max(args.v, 2))))
        src, dst = rmat(bits, args.e, seed=args.seed)
        v = 1 << bits
    else:
        src, dst, v = generate(args.dataset or "dblp", seed=args.seed,
                               scale=args.scale)

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    write_edge_list(args.out, src, dst, v, seed=args.seed,
                    shuffle=args.shuffle, one_indexed=args.one_indexed,
                    dup_frac=args.dup_frac, self_loops=args.self_loops,
                    header=not args.no_header,
                    comment=f"ssumm synthetic fixture seed={args.seed}")
    print(f"{args.out}: |V|={v} |E|={len(src)} "
          f"({os.path.getsize(args.out)} bytes)")
    return args.out


if __name__ == "__main__":
    main()
