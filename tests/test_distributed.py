"""Distributed SSumM correctness on a multi-device host mesh.

jax locks the device count at first init, so the 8-device check runs in a
subprocess (tests/dist_check.py) — the same pattern the dry-run uses."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(script: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", script)],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_feed_equivalence_and_shard_boundaries():
    """Out-of-core cache feed ≡ in-memory shard path on an 8-device mesh
    (bit-identical metrics, exact per-shard contents, |E| % n_dev != 0,
    all-padding trailing shards) — body in tests/feed_check.py."""
    rec = _run_check("feed_check.py")
    assert rec["ok"] and rec["dropped"] > 0
    # the feed staged at most one shard of host memory, never ~4·|E|
    assert rec["peak_staging_bytes"] == rec["shard_bytes"]
    assert rec["peak_staging_bytes"] < 4 * rec["E"]


@pytest.mark.slow
def test_distributed_step_parity_and_progress():
    rec = _run_check("dist_check.py")
    assert rec["ok"] and rec["merged"] > 0
    # the edge-sharded sparsify phase ran and actually dropped superedges
    # (its drop-mask/metric parity asserts live inside dist_check.py)
    assert rec["sparsify_dropped"] > 0


@pytest.mark.slow
def test_compressed_allreduce_wire_accounting():
    """The shard_map'd compressed all-reduce moves exactly the payload
    ``payload_bytes`` prices, sums correctly for every wire format, and
    keeps the error-feedback residual device-local — body in
    tests/wire_check.py (single-process 8-device mesh here; the
    2-process run is tests/multihost_check.py's wire leg)."""
    rec = _run_check("wire_check.py")
    assert rec["ok"] and rec["process_count"] == 1
    for kind in ("none", "int8", "topk"):
        wb = rec["wire_bytes"][kind]
        assert wb["measured"] == wb["priced"]


@pytest.mark.slow
def test_routed_query_engine_parity():
    """Owner-routed AND memory-partitioned query serving ≡ single-device
    engine, bit-identical, on an 8-device mesh and again after an elastic
    8→4 shrink (routing/halo table rebuild) — body in
    tests/query_serve_check.py."""
    rec = _run_check("query_serve_check.py")
    assert rec["ok"] and rec["served"] > 0
    # blocks really spread across owners — parity is only meaningful if
    # more than one device answered queries
    assert rec["routed_devices_8"] > 1
    assert rec["routed_devices_4"] > 1
    # partitioned tier: non-trivial partition, real halo traffic, a
    # forced second-hop route, and per-device residency strictly below
    # the replicated tier's full row storage
    assert rec["partitioned_ok"] and rec["served_partitioned"] > 0
    assert rec["partitioned_devices_8"] > 1
    assert rec["halo_max"] > 0
    assert rec["dense_rows"] > 0
    assert rec["resident_bytes_per_device"] < rec["replicated_row_bytes"]
