"""Distributed SSumM correctness on a multi-device host mesh.

jax locks the device count at first init, so the 8-device check runs in a
subprocess (tests/dist_check.py) — the same pattern the dry-run uses."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_distributed_step_parity_and_progress():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "dist_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    last = out.stdout.strip().splitlines()[-1]
    rec = json.loads(last)
    assert rec["ok"] and rec["merged"] > 0
    # the edge-sharded sparsify phase ran and actually dropped superedges
    # (its drop-mask/metric parity asserts live inside dist_check.py)
    assert rec["sparsify_dropped"] > 0
