"""Competitor baselines (k-Gs, S2L, SAA-Gs): valid outputs, target respected,
evaluation parity with the dense brute force."""

import numpy as np
import pytest

from repro.baselines import (
    evaluate_partition,
    summarize_kgs,
    summarize_s2l,
    summarize_saa_gs,
)
from repro.core import evaluate as ev
from repro.graphs import generate


def small_graph(seed=0):
    return generate("ego-facebook", seed=seed, scale=0.05)


@pytest.mark.parametrize("method,fn", [
    ("kgs", summarize_kgs),
    ("s2l", summarize_s2l),
    ("saa_gs", summarize_saa_gs),
])
def test_baseline_reaches_target(method, fn):
    src, dst, v = small_graph()
    frac = 0.3
    res = fn(src, dst, v, target_frac=frac, seed=0)
    target = max(int(frac * v), 2)
    # s2l's k-means may leave some clusters empty; greedy methods hit exactly
    assert res.num_supernodes <= max(target, 2) * (1.15 if method == "s2l" else 1.0)
    assert res.num_supernodes >= 2
    assert np.isfinite(res.re1) and res.re1 >= 0
    assert res.size_bits > 0
    # the partition is total
    assert res.node2super.shape[0] == v


def test_kgs_error_monotone_in_target():
    src, dst, v = small_graph(seed=2)
    coarse = summarize_kgs(src, dst, v, target_frac=0.1, seed=2)
    fine = summarize_kgs(src, dst, v, target_frac=0.5, seed=2)
    assert fine.re1 <= coarse.re1 * 1.05


def test_evaluate_partition_matches_dense():
    rng = np.random.default_rng(4)
    src, dst, v = small_graph(seed=4)
    n2s_raw = rng.integers(0, 20, v)
    # canonical representative ids
    reps = {}
    n2s = np.array([reps.setdefault(g, u) for u, g in enumerate(n2s_raw)])
    res = evaluate_partition(src, dst, v, n2s)

    from repro.core.types import SummaryResult

    size = np.bincount(n2s, minlength=v)
    from repro.baselines.common import pair_counts
    lo, hi, cnt = pair_counts(src, dst, n2s)
    sr = SummaryResult(
        node2super=n2s.astype(np.int32), super_size=size.astype(np.int32),
        edge_lo=lo, edge_hi=hi, edge_w=cnt.astype(np.int64),
        num_supernodes=res.num_supernodes, num_superedges=res.num_superedges,
        size_bits=0, input_size_bits=0, re1=0, re2=0, mdl_cost=0,
        iterations_run=0,
    )
    a = ev.dense_adjacency(src, dst, v)
    a_hat = ev.reconstruct_dense(sr)
    np.testing.assert_allclose(res.re1, ev.re_p_dense(a, a_hat, 1),
                               rtol=1e-6, atol=1e-10)
    np.testing.assert_allclose(res.re2, ev.re_p_dense(a, a_hat, 2),
                               rtol=1e-6, atol=1e-10)
    np.testing.assert_allclose(res.size_bits, ev.summary_size_bits_dense(sr),
                               rtol=1e-6)


def test_ssumm_beats_baselines_at_equal_size():
    """The paper's headline (Fig. 4), trend-level: at comparable output
    size, SSumM's RE₁ is never materially worse than the competitors'."""
    from repro.core import SummaryConfig, summarize

    src, dst, v = generate("ego-facebook", seed=1, scale=0.1)
    ss = summarize(src, dst, v, SummaryConfig(T=10, k_frac=0.3, seed=1))
    kg = summarize_kgs(src, dst, v, target_frac=0.3, seed=1)
    sa = summarize_saa_gs(src, dst, v, target_frac=0.3, seed=1)
    # same-or-less size, same-or-better error vs the sketch baseline
    assert ss.size_bits <= max(kg.size_bits, sa.size_bits)
    assert ss.re1 <= sa.re1 * 1.1
