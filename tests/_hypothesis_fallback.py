"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The test image has no network access, so ``hypothesis`` may be absent;
``conftest.py`` installs this module into ``sys.modules`` in that case.
It implements just the surface the property tests here use — ``given``,
``settings``, and the ``integers`` / ``floats`` / ``sampled_from`` /
``data`` strategies — and turns each property into ``max_examples``
deterministic cases drawn from a per-example seeded RNG, so the
properties still execute as plain pytest tests (with less adversarial
search than real hypothesis shrinking, but the same assertions).
"""

from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(elements):
    seq = list(elements)
    return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def booleans():
    return Strategy(lambda rng: bool(rng.integers(2)))


class DataObject:
    """Interactive draws (``st.data()``) against the example's RNG."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        del label
        return strategy.example_from(self._rng)


def data():
    return Strategy(lambda rng: DataObject(rng))


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        params = [
            p
            for p in inspect.signature(fn).parameters.values()
            if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
        ]
        # hypothesis binds positional strategies to the RIGHTMOST params
        # (leftmost stay free for pytest fixtures) — match that
        tail = params[len(params) - len(arg_strategies):]
        positional = {p.name: s for p, s in zip(tail, arg_strategies)}

        strategies = {**positional, **kw_strategies}

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above @given (attribute lands on wrapper)
            # or below it (attribute lands on fn, copied here by wraps)
            conf = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", {}
            )
            n = conf.get("max_examples", DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng(1000 + i)
                drawn = {
                    name: s.example_from(rng)
                    for name, s in strategies.items()
                }
                fn(*args, **drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(
            [p for p in params if p.name not in strategies]
        )
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+``.strategies``)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "data"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
