"""Summary-graph analytics (paper benefit (b)): block-space PageRank and
degree queries match dense computation on the reconstructed Ĝ, and
approximate the original graph."""

import numpy as np
import pytest

from repro.core import SummaryConfig, summarize
from repro.core import evaluate as ev
from repro.core.queries import expected_degree, pagerank_summary
from repro.graphs import generate


def _dense_pagerank(a: np.ndarray, damping=0.85, iters=100):
    v = a.shape[0]
    deg = a.sum(1)
    p = np.full(v, 1.0 / v)
    for _ in range(iters):
        share = np.where(deg > 0, p / np.maximum(deg, 1e-300), 0.0)
        new = a.T @ share
        dangling = float(p[deg <= 0].sum())
        p = (1 - damping) / v + damping * (new + dangling / v)
    return p


@pytest.fixture(scope="module")
def summary():
    src, dst, v = generate("ego-facebook", seed=2, scale=0.06)
    res = summarize(src, dst, v, SummaryConfig(T=10, k_frac=0.4, seed=2))
    return src, dst, v, res


def test_block_pagerank_matches_dense_reconstruction(summary):
    src, dst, v, res = summary
    a_hat = ev.reconstruct_dense(res)
    want = _dense_pagerank(a_hat)
    got = pagerank_summary(res, iters=100)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-9)


def test_pagerank_approximates_original(summary):
    src, dst, v, res = summary
    a = ev.dense_adjacency(src, dst, v)
    exact = _dense_pagerank(a)
    approx = pagerank_summary(res, iters=100)
    corr = np.corrcoef(exact, approx)[0, 1]
    assert corr > 0.85, corr


def test_expected_degree_matches_dense(summary):
    src, dst, v, res = summary
    a_hat = ev.reconstruct_dense(res)
    for u in (0, 5, v // 2, v - 1):
        np.testing.assert_allclose(expected_degree(res, u),
                                   a_hat[u].sum(), rtol=1e-6, atol=1e-9)
