"""Per-architecture smoke tests (deliverable f): reduced configs of each
assigned family run one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, get_smoke_config
from repro.models.api import build_model, input_specs
from repro.optim import adamw_init


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["img_emb"] = jnp.zeros((b, cfg.img_tokens, cfg.img_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = model.forward(params, batch, None, False)
    exp_s = 16 + (cfg.img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    opt = adamw_init(params)
    p2, o2, metrics = model.train_step(params, opt, batch, remat=False)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32) - x[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), p2, params),
        0.0,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32)
    sb = {
        "token": jnp.ones((2,), jnp.int32),
        "pos": jnp.asarray(3, jnp.int32),
        "cache": cache,
    }
    logits, new_cache = model.serve_step(params, sb)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_input_specs(arch):
    """Full configs: every applicable shape yields well-formed specs without
    allocating anything."""
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert "train_4k" in shapes
    if arch in ("xlstm_350m", "zamba2_7b", "h2o_danube_1_8b"):
        assert "long_500k" in shapes  # sub-quadratic archs
    else:
        assert "long_500k" not in shapes
    for s in shapes:
        specs = input_specs(cfg, s)
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_scale(arch):
    """Analytic param counts are within 2× of the architecture's nameplate
    size (sanity for the 6·N·D roofline terms)."""
    cfg = get_config(arch)
    nameplate = {
        "xlstm_350m": 0.35e9,
        "granite_moe_3b_a800m": 3.0e9,
        "moonshot_v1_16b_a3b": 16e9,
        "gemma_7b": 8.5e9,
        "deepseek_coder_33b": 33e9,
        "qwen2_5_14b": 14e9,
        "h2o_danube_1_8b": 1.8e9,
        "zamba2_7b": 7e9,
        "whisper_large_v3": 1.5e9,
        "paligemma_3b": 2.8e9,
    }[arch]
    n = cfg.param_count()
    assert 0.4 * nameplate < n < 2.5 * nameplate, (arch, n, nameplate)
