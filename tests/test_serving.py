"""Continuous-batching correctness: a request's greedy output must be
independent of what else is in the batch and of admission timing."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import BatchServer, Request


def _serve(cfg, reqs, slots, seed=0):
    server = BatchServer(cfg, slots=slots, max_len=64, seed=seed)
    for r in reqs:
        server.submit(r)
    while server.step():
        pass
    return {r.rid: list(r.out) for r in server.done}


def _requests(cfg, n, gen_len=6, seed=0, ragged=False):
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        ln = 4 + (rid % 3 if ragged else 0)
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, ln).astype(np.int32),
            max_new=gen_len,
        ))
    return out


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "xlstm_350m"])
def test_batching_invariance(arch):
    """Outputs with slots=1 (pure sequential) == slots=3 (batched, ragged
    admissions) for identical requests."""
    cfg = get_smoke_config(arch)
    reqs_a = _requests(cfg, 5, ragged=True)
    reqs_b = _requests(cfg, 5, ragged=True)
    solo = _serve(cfg, reqs_a, slots=1)
    batched = _serve(cfg, reqs_b, slots=3)
    assert solo.keys() == batched.keys()
    for rid in solo:
        assert solo[rid] == batched[rid], (
            f"{arch}: request {rid} depends on batching: "
            f"{solo[rid]} vs {batched[rid]}"
        )


def test_all_requests_complete_and_lengths():
    cfg = get_smoke_config("h2o_danube_1_8b")
    reqs = _requests(cfg, 7, gen_len=5, ragged=True)
    out = _serve(cfg, reqs, slots=2)
    assert len(out) == 7
    assert all(len(v) == 5 for v in out.values())
