"""Further-sparsification unit tests (Sect. 3.2.4): the footnote-4 delta
orderings, the ξ degenerate branches, and the histogram order-statistic
backend (radix_select_kth) that the distributed path psums."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SummaryConfig, costs, sparsify, summarize
from repro.core.types import SummaryState, make_graph
from repro.graphs import generate


def _graph_and_state(seed=0, v=60, e_target=320, n_groups=14):
    """Random graph + random canonical partition (exact supernode sizes)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e_target)
    dst = rng.integers(0, v, e_target)
    keep = src != dst
    graph, _ = make_graph(src[keep], dst[keep], v)
    groups = rng.integers(0, n_groups, v)
    reps = np.full(n_groups, -1, np.int64)
    n2s = np.zeros(v, np.int64)
    for u in range(v):
        g = groups[u]
        if reps[g] < 0:
            reps[g] = u
        n2s[u] = reps[g]
    size = np.bincount(n2s, minlength=v)
    state = SummaryState(
        node2super=jnp.asarray(n2s, jnp.int32),
        size=jnp.asarray(size, jnp.int32),
        rng=jnp.zeros((2,), jnp.uint32),
        t=jnp.asarray(1, jnp.int32),
    )
    return graph, state, v, graph.num_edges


def _merged_state(seed=0, scale=0.05, T=5):
    """Partition after real merge rounds — many MDL-kept superedges, unlike
    a random partition (which the Eq. 11 rule rejects almost entirely)."""
    src, dst, v = generate("ego-facebook", seed=seed, scale=scale)
    graph, _ = make_graph(src, dst, v)
    res = summarize(src, dst, v,
                    SummaryConfig(T=T, k_frac=0.5, seed=seed,
                                  ensure_budget=False))
    state = SummaryState(
        node2super=jnp.asarray(res.node2super),
        size=jnp.asarray(res.super_size),
        rng=jnp.zeros((2,), jnp.uint32),
        t=jnp.asarray(T, jnp.int32),
    )
    return graph, state, v, graph.num_edges


# ---------------------------------------------------------------------------
# degenerate ξ branches
# ---------------------------------------------------------------------------


def test_xi_zero_budget_already_met():
    """k ≥ Size(Ḡ) → ξ = 0 → no superedge is dropped, metrics unchanged."""
    graph, state, v, e = _graph_and_state(seed=1)
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    before = costs.summary_metrics(pt, state, v, e)
    k_bits = float(before["size_bits"]) * 2.0
    drop, after = sparsify.further_sparsify(pt, state, v, e, k_bits)
    assert not bool(jnp.any(drop))
    assert float(after["size_bits"]) == float(before["size_bits"])
    assert float(after["re1"]) == float(before["re1"])
    assert float(after["num_superedges"]) == float(before["num_superedges"])


def test_xi_exceeds_p_count_drops_everything():
    """k below even the membership term → ξ ≥ |P| → every kept superedge
    goes; what remains is the |V|log₂|S| membership encoding."""
    graph, state, v, e = _graph_and_state(seed=2)
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    before = costs.summary_metrics(pt, state, v, e)
    drop, after = sparsify.further_sparsify(pt, state, v, e, k_bits=1.0)
    np.testing.assert_array_equal(np.asarray(drop), np.asarray(before["keep"]))
    assert float(after["num_superedges"]) == 0.0
    assert float(after["size_bits"]) == float(after["membership_bits"])
    # every subedge is now unexplained: RE₁ = 2|E|/(|V|(|V|-1))
    np.testing.assert_allclose(float(after["re1"]),
                               2.0 * e / (v * (v - 1.0)), rtol=1e-5)


# ---------------------------------------------------------------------------
# ΔRE_p ordering (footnote 4), p ∈ {1, 2}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("error_p", [1, 2])
def test_drop_set_is_minimum_delta_prefix(error_p):
    """Dropped superedges are exactly a ≤-prefix of the ΔRE_p order: every
    dropped delta ≤ every surviving delta, and at least ξ are dropped."""
    graph, state, v, e = _merged_state(seed=0)
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    before = costs.summary_metrics(pt, state, v, e)
    k_bits = 0.7 * float(before["size_bits"])
    drop, after = sparsify.further_sparsify(pt, state, v, e, k_bits,
                                            error_p=error_p)
    keep = np.asarray(before["keep"])
    dropped = np.asarray(drop)
    assert dropped.sum() > 0 and (keep & ~dropped).sum() > 0
    assert not (dropped & ~keep).any()  # only kept superedges can drop

    pi = np.asarray(costs.pair_pi(pt, state.size))
    cnt = np.asarray(pt.cnt)
    sigma = cnt / np.maximum(pi, 1.0)
    delta = (2.0 * sigma - 1.0) * cnt if error_p == 1 else cnt * sigma
    assert delta[dropped].max() <= delta[keep & ~dropped].min()

    xi = int(sparsify.sparsify_xi(before["size_bits"], k_bits,
                                  before["num_supernodes"],
                                  before["omega_max"]))
    assert dropped.sum() >= xi  # ties at the threshold may exceed ξ
    assert float(after["size_bits"]) <= k_bits * (1 + 1e-6)


def test_error_p_changes_the_ordering():
    """ΔRE₁ = (2σ−1)|E_AB| and ΔRE₂² = σ|E_AB| rank pairs differently:
    a sparse heavy superedge (σ small, cnt big) is cheap to drop under p=1
    (negative delta) but expensive under p=2."""
    cnt = jnp.asarray([9.0, 2.0])
    pi = jnp.asarray([100.0, 2.0])  # σ = 0.09 vs 1.0
    d1 = np.asarray(sparsify.sparsify_deltas(cnt, pi, 1))
    d2 = np.asarray(sparsify.sparsify_deltas(cnt, pi, 2))
    assert d1[0] < d1[1]  # p=1 drops the sparse heavy pair first
    assert d2[0] < d2[1] or d2[0] == pytest.approx(0.81)
    np.testing.assert_allclose(d1, [(2 * 0.09 - 1) * 9.0, 2.0], rtol=1e-5)
    np.testing.assert_allclose(d2, [0.81, 2.0], rtol=1e-5)


# ---------------------------------------------------------------------------
# histogram selection backend ≡ sort backend
# ---------------------------------------------------------------------------


def test_ordered_key_monotone_roundtrip():
    x = jnp.asarray([-3.5, -0.0, 0.0, 1e-20, 7.25, -1e9, 3.4e38],
                    jnp.float32)
    keys = sparsify.ordered_key_from_f32(x)
    back = sparsify.f32_from_ordered_key(keys)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    order_f = np.argsort(np.asarray(x), kind="stable")
    order_k = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(x)[order_f],
                                  np.asarray(x)[order_k])


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_radix_select_matches_sort(seed):
    rng = np.random.default_rng(seed)
    n = 257
    vals = rng.normal(0.0, 100.0, n).astype(np.float32)
    vals[rng.random(n) < 0.3] = rng.choice([-2.0, 0.0, 5.5])  # duplicates
    valid = rng.random(n) < 0.8
    ordered = np.sort(vals[valid])
    keys = sparsify.ordered_key_from_f32(jnp.asarray(vals))
    for k in [0, 1, len(ordered) // 2, len(ordered) - 1]:
        got = sparsify.radix_select_kth(keys, jnp.asarray(valid),
                                        jnp.int32(k))
        got_f = float(sparsify.f32_from_ordered_key(got))
        assert got_f == ordered[k], (k, got_f, ordered[k])


def test_select_delta_xi_matches_sort_threshold():
    """The histogram Δ_ξ equals the sort-based order[ξ−1] on real deltas."""
    graph, state, v, e = _merged_state(seed=0)
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    m = costs.summary_metrics(pt, state, v, e)
    keep = m["keep"]
    pi = costs.pair_pi(pt, state.size)
    delta = sparsify.sparsify_deltas(pt.cnt, pi, 1)
    p_count = int(m["num_superedges"])
    for xi in [1, 2, p_count // 2, p_count]:
        want = float(jnp.sort(jnp.where(keep, delta, jnp.inf))[xi - 1])
        got = float(sparsify.select_delta_xi(delta, keep, jnp.int32(xi)))
        assert got == want, (xi, got, want)
