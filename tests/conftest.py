"""Test-session setup: fall back to the deterministic hypothesis stub when
the real library is unavailable (no-network test images)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:
    import hypothesis  # noqa: F401  (prefer the real library when present)
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
