"""MoE dispatch: GSPMD-vs-a2a parity (multi-device, subprocess) and local
dispatch invariants."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dispatch_buckets_roundtrip():
    from repro.models.moe import _dispatch_to_buckets

    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal((20, 3)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 4, 20), jnp.int32)
    buckets, order, flat, ok = _dispatch_to_buckets(vals, keys, 4, cap=8)
    assert bool(jnp.all(ok))  # cap 8 ≥ worst bucket load here? verify:
    # every row landed in the bucket of its key
    got = np.asarray(buckets).reshape(32, 3)
    for i in range(20):
        r = int(np.asarray(order)[i])
        f = int(np.asarray(flat)[i])
        np.testing.assert_array_equal(got[f], np.asarray(vals)[r])


def test_dispatch_buckets_capacity_drop():
    from repro.models.moe import _dispatch_to_buckets

    vals = jnp.ones((10, 2), jnp.float32)
    keys = jnp.zeros((10,), jnp.int32)  # all to bucket 0, cap 4
    buckets, _, _, ok = _dispatch_to_buckets(vals, keys, 2, cap=4)
    assert int(jnp.sum(ok)) == 4
    assert float(jnp.sum(buckets)) == 4 * 2


@pytest.mark.slow
def test_a2a_matches_gspmd_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "moe_check.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
