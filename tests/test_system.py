"""End-to-end system tests: the three drivers run as a user would run them
(in-process via their main(argv)), exercising mesh planning, sharded init,
checkpointing, and the serving scheduler on CPU."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    res = main([
        "--arch", "h2o_danube_1_8b", "--smoke", "--steps", "8",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4", "--log-every", "100",
    ])
    assert res["steps"] == 8
    assert np.isfinite(res["loss_last"])
    # checkpoints committed: async at 4, 8 + final at 8
    from repro.runtime import CheckpointManager

    assert CheckpointManager(str(tmp_path)).latest_step() == 8


def test_train_driver_resume(tmp_path):
    from repro.launch.train import main

    main(["--arch", "xlstm_350m", "--smoke", "--steps", "6", "--batch", "2",
          "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
          "--log-every", "100"])
    res = main(["--arch", "xlstm_350m", "--smoke", "--steps", "9",
                "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
                "--resume", "--ckpt-every", "3", "--log-every", "100"])
    assert res["steps"] == 3  # resumed at 6, ran 6..8


def test_train_loss_decreases():
    """~40 steps on the structured synthetic corpus must cut the loss."""
    from repro.launch.train import main

    res = main(["--arch", "qwen2_5_14b", "--smoke", "--steps", "40",
                "--batch", "8", "--seq", "64", "--lr", "3e-3",
                "--log-every", "100"])
    assert res["loss_last"] < res["loss_first"] - 0.3, res


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    res = main(["--arch", "gemma_7b", "--smoke", "--requests", "3",
                "--slots", "2", "--prompt-len", "4", "--gen-len", "4",
                "--max-len", "32"])
    assert res["requests"] == 3
    assert res["tokens"] == 3 * 4
    assert res["tok_per_s"] > 0


def test_summarize_driver_end_to_end():
    from repro.launch.summarize import main

    res = main(["--dataset", "ego-facebook", "--scale", "0.05",
                "--k-frac", "0.3", "--T", "5"])
    assert res["relative_size"] <= 0.3 + 1e-6
    assert np.isfinite(res["re1"])


def test_grad_accumulation_matches_full_batch():
    """accum=2 must produce (numerically) the same update as accum=1."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.dist import microbatch_grads
    from repro.models.api import build_model

    cfg = get_smoke_config("h2o_danube_1_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab)}

    def loss_fn(p, b):
        return model.loss(p, b, None, remat=False)

    l1, _, g1 = microbatch_grads(loss_fn, params, batch, accum=1)
    l2, _, g2 = microbatch_grads(loss_fn, params, batch, accum=2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
    flat1 = jnp.concatenate([x.ravel().astype(jnp.float32)
                             for x in jax.tree.leaves(g1)])
    flat2 = jnp.concatenate([x.ravel().astype(jnp.float32)
                             for x in jax.tree.leaves(g2)])
    np.testing.assert_allclose(np.asarray(flat1), np.asarray(flat2),
                               rtol=5e-3, atol=5e-5)
