"""Differential tests for the batched device query engine (DESIGN.md §14).

Property: for ANY summary graph — hypothesis-driven random partitions +
superedge sets, plus the edge cases the old suite missed (self-loop-only
blocks, dangling blocks, singleton supernodes, empty superedge set,
ξ-dropped summaries) — the batched JAX answers equal the single-query
numpy `repro.core.queries` answers equal the dense-reconstruction ground
truth. PR 10 extends the property to the analytics kinds: cut weight,
conductance, and k-hop size agree with numpy at 1e-9 and with the dense
Â (indicator bilinear forms / support BFS) over random node sets
including empty A, A = all nodes, and k = 0. Count/size-free float
comparisons are pinned far below the documented 1e-6 drift budget (both
paths are float64)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SummaryConfig, summarize
from repro.core import evaluate as ev
from repro.core import queries as Q
from repro.core.queries_jax import (
    KIND_ADJACENCY,
    KIND_CONDUCTANCE,
    KIND_CUT,
    KIND_DEGREE,
    KIND_KHOP,
    KIND_PAGERANK,
    KIND_TRIANGLE,
    QueryEngine,
    pack_set_counts,
)
from repro.core.types import SummaryResult
from repro.graphs import generate


def _make_result(node2super: np.ndarray, pairs: list) -> SummaryResult:
    """A SummaryResult carrying just the summary graph (metrics zeroed)."""
    v = node2super.shape[0]
    size = np.bincount(node2super, minlength=v).astype(np.int32)
    lo = np.array([p[0] for p in pairs], np.int32)
    hi = np.array([p[1] for p in pairs], np.int32)
    w = np.array([p[2] for p in pairs], np.int64)
    return SummaryResult(
        node2super=node2super.astype(np.int32), super_size=size,
        edge_lo=lo, edge_hi=hi, edge_w=w,
        num_supernodes=int(np.unique(node2super).shape[0]),
        num_superedges=len(pairs), size_bits=0.0, input_size_bits=1.0,
        re1=0.0, re2=0.0, mdl_cost=0.0, iterations_run=0)


def _random_summary(rng, v_max: int = 28, edge_frac: float = 0.5):
    """Random partition of [0, V) into supernodes + random valid superedge
    set (weights within pair capacity, zero-capacity self pairs never
    emitted — they have no Π to spread mass over)."""
    v = int(rng.integers(4, v_max))
    s = int(rng.integers(1, v + 1))
    ids = np.sort(rng.choice(v, size=s, replace=False)).astype(np.int32)
    node2super = rng.choice(ids, size=v).astype(np.int32)
    node2super[rng.permutation(v)[:s]] = ids  # every block nonempty
    live = np.unique(node2super)
    n = np.bincount(node2super, minlength=v)[live].astype(np.int64)
    pairs = []
    for i, a in enumerate(live):
        for j in range(i, len(live)):
            b = live[j]
            cap = n[i] * (n[i] - 1) // 2 if a == b else n[i] * n[j]
            if cap > 0 and rng.random() < edge_frac:
                pairs.append((int(a), int(b),
                              int(rng.integers(1, cap + 1))))
    return _make_result(node2super, pairs)


def _dense_pagerank(a: np.ndarray, damping=0.85, iters=100):
    v = a.shape[0]
    deg = a.sum(1)
    p = np.full(v, 1.0 / v)
    for _ in range(iters):
        share = np.where(deg > 0, p / np.maximum(deg, 1e-300), 0.0)
        new = a.T @ share
        dangling = float(p[deg <= 0].sum())
        p = (1 - damping) / v + damping * (new + dangling / v)
    return p


def _assert_differential(res: SummaryResult, check_dense_pagerank=True):
    """Batched JAX == single-query numpy == dense reconstruction."""
    v = res.node2super.shape[0]
    eng = QueryEngine(res)
    a_hat = ev.reconstruct_dense(res)
    rng = np.random.default_rng(0)

    # --- expected degree over every node -------------------------------
    deg_jax = eng.expected_degree(np.arange(v))
    deg_np = np.array([Q.expected_degree(res, u) for u in range(v)])
    np.testing.assert_allclose(deg_jax, deg_np, rtol=1e-12, atol=1e-15)
    np.testing.assert_allclose(deg_np, a_hat.sum(1), rtol=1e-9, atol=1e-12)

    # --- adjacency: random pairs + diagonal + same-block pairs ---------
    u = np.concatenate([rng.integers(0, v, 40), np.arange(v)[:8]])
    w = np.concatenate([rng.integers(0, v, 40), np.arange(v)[:8]])
    adj_jax = eng.adjacency_weight(u.astype(np.int32), w.astype(np.int32))
    adj_np = np.array([Q.adjacency_weight(res, a, b) for a, b in zip(u, w)])
    np.testing.assert_allclose(adj_jax, adj_np, rtol=1e-12, atol=1e-15)
    np.testing.assert_allclose(adj_np, a_hat[u, w], rtol=1e-9, atol=1e-12)

    # --- PageRank ------------------------------------------------------
    pr_jax = eng.pagerank_nodes(np.arange(v))
    pr_np = Q.pagerank_summary(res)
    np.testing.assert_allclose(pr_jax, pr_np, rtol=1e-9, atol=1e-12)
    if check_dense_pagerank:
        eng100 = QueryEngine(res, pagerank_iters=100)
        np.testing.assert_allclose(
            eng100.pagerank_nodes(np.arange(v)), _dense_pagerank(a_hat),
            rtol=5e-4, atol=1e-9)

    # --- triangle density ---------------------------------------------
    tri_jax = eng.triangle_density()
    tri_np = Q.triangle_density(res)
    np.testing.assert_allclose(tri_jax, tri_np, rtol=1e-9, atol=1e-12)
    if not np.any(res.edge_lo == res.edge_hi):
        # without self-superedges, the block-triple formula is exactly the
        # dense E[#triangles] = tr(Â³)/6
        tri_dense = float(np.trace(a_hat @ a_hat @ a_hat) / 6.0)
        np.testing.assert_allclose(tri_np, tri_dense, rtol=1e-8, atol=1e-9)

    # --- cut weight: random pairs + empty A + A = everything -----------
    sets_a = [rng.choice(v, size=int(rng.integers(0, v + 1)),
                         replace=False) for _ in range(6)]
    sets_b = [rng.choice(v, size=int(rng.integers(0, v + 1)),
                         replace=False) for _ in range(6)]
    sets_a += [np.array([], np.int64), np.arange(v)]
    sets_b += [rng.choice(v, size=max(1, v // 2), replace=False),
               np.arange(v)]
    cut_jax = eng.cut_weight(sets_a, sets_b)
    cut_np = np.array([Q.cut_weight(res, a, b)
                       for a, b in zip(sets_a, sets_b)])
    np.testing.assert_allclose(cut_jax, cut_np, rtol=0, atol=1e-9)
    for got, a, b in zip(cut_np, sets_a, sets_b):
        ia = np.zeros(v)
        ia[np.asarray(a, np.int64)] = 1.0
        ib = np.zeros(v)
        ib[np.asarray(b, np.int64)] = 1.0
        np.testing.assert_allclose(got, ia @ a_hat @ ib,
                                   rtol=1e-9, atol=1e-9)

    # --- conductance: same sets (incl. empty and full A -> 0) ----------
    cond_jax = eng.conductance(sets_a)
    cond_np = np.array([Q.conductance(res, a) for a in sets_a])
    np.testing.assert_allclose(cond_jax, cond_np, rtol=0, atol=1e-9)
    for got, a in zip(cond_np, sets_a):
        ia = np.zeros(v)
        ia[np.asarray(a, np.int64)] = 1.0
        dense_cut = ia @ a_hat @ (1.0 - ia)
        denom = min(float(ia @ a_hat.sum(1)),
                    float((1.0 - ia) @ a_hat.sum(1)))
        want = dense_cut / denom if denom > 0 else 0.0
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
    assert cond_np[-2] == 0.0 and cond_np[-1] == 0.0  # empty / full A

    # --- k-hop size: k = 0 through k = khop_max vs dense BFS -----------
    # (k is capped at the engine's khop_max BFS budget: below it the
    # jitted fixpoint loop runs exactly k steps like the numpy reference)
    ku = rng.integers(0, v, 10).astype(np.int64)
    kk = np.concatenate([[0, 0], rng.integers(1, 5, 6),
                         [eng.khop_max, eng.khop_max]])
    khop_jax = eng.k_hop_size(ku, kk[:10])
    khop_np = np.array([Q.k_hop_size(res, int(a), int(k))
                        for a, k in zip(ku, kk)])
    np.testing.assert_allclose(khop_jax, khop_np, rtol=0, atol=1e-9)
    support = a_hat > 0
    for got, a, k in zip(khop_np, ku, kk):
        reach = np.zeros(v, bool)
        reach[a] = True
        for _ in range(min(int(k), v)):
            reach = reach | (support @ reach)
        np.testing.assert_allclose(got, float(reach.sum()),
                                   rtol=1e-9, atol=1e-9)
    assert np.all(khop_np[:2] == 1.0)  # k = 0 is just the node itself

    # --- fused mixed-kind batch == the per-kind kernels ----------------
    b = 16
    kinds = np.array([KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK,
                      KIND_TRIANGLE, KIND_KHOP, KIND_CUT,
                      KIND_CONDUCTANCE, KIND_DEGREE] * (b // 8), np.int32)
    bu = rng.integers(0, v, b).astype(np.int32)
    bv = rng.integers(0, v, b).astype(np.int32)
    bv[kinds == KIND_KHOP] = rng.integers(0, 4, (kinds == KIND_KHOP).sum())
    bsets_a = [rng.choice(v, size=int(rng.integers(0, v + 1)),
                          replace=False) for _ in range(b)]
    bsets_b = [rng.choice(v, size=int(rng.integers(0, v + 1)),
                          replace=False) for _ in range(b)]
    ca, cb, ov = pack_set_counts(eng.bs, kinds, bsets_a, bsets_b)
    ans = eng.answer_batch(kinds, bu, bv, ca, cb, ov)
    for s in range(b):
        if kinds[s] == KIND_DEGREE:
            want = deg_np[bu[s]]
        elif kinds[s] == KIND_ADJACENCY:
            want = Q.adjacency_weight(res, bu[s], bv[s])
        elif kinds[s] == KIND_PAGERANK:
            want = pr_np[bu[s]]
        elif kinds[s] == KIND_KHOP:
            want = Q.k_hop_size(res, int(bu[s]), int(bv[s]))
        elif kinds[s] == KIND_CUT:
            want = Q.cut_weight(res, bsets_a[s], bsets_b[s])
        elif kinds[s] == KIND_CONDUCTANCE:
            want = Q.conductance(res, bsets_a[s])
        else:
            want = tri_np
        np.testing.assert_allclose(ans[s], want, rtol=1e-9, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_differential_random_summaries(seed):
    rng = np.random.default_rng(seed)
    _assert_differential(_random_summary(rng),
                         check_dense_pagerank=(seed % 3 == 0))


def test_empty_superedge_set():
    """ξ dropped everything / no edges survived: all queries are defined
    (degree 0, uniform PageRank, zero triangles)."""
    rng = np.random.default_rng(7)
    res = _random_summary(rng, edge_frac=0.0)
    assert res.num_superedges == 0
    _assert_differential(res)
    eng = QueryEngine(res)
    v = res.node2super.shape[0]
    assert np.all(eng.expected_degree(np.arange(v)) == 0.0)
    np.testing.assert_allclose(eng.pagerank_nodes(np.arange(v)), 1.0 / v)
    assert eng.triangle_density() == 0.0


def test_self_loop_only_blocks():
    """Blocks whose only superedge is their self-loop (plus a singleton
    block, whose zero-capacity self pair must never materialize)."""
    node2super = np.array([0, 0, 0, 3, 3, 5], np.int32)
    res = _make_result(node2super, [(0, 0, 3), (3, 3, 1)])
    _assert_differential(res)
    eng = QueryEngine(res)
    # block {0,1,2}: σ = 3/C(3,2) = 1 → expected degree 2 (clique)
    np.testing.assert_allclose(eng.expected_degree(np.array([0])), [2.0])
    # singleton block 5 is dangling
    np.testing.assert_allclose(eng.expected_degree(np.array([5])), [0.0])


def test_dangling_and_singleton_blocks():
    """Dangling blocks redistribute PageRank mass uniformly; singleton
    supernodes answer adjacency through their cross σ only."""
    node2super = np.array([0, 0, 2, 3, 3, 3, 6], np.int32)
    res = _make_result(node2super, [(0, 2, 1), (2, 3, 2)])  # 6 dangling
    _assert_differential(res)
    assert Q.expected_degree(res, 6) == 0.0
    assert Q.adjacency_weight(res, 2, 6) == 0.0
    # singleton block 2 ↔ pair block {0,1}: σ = 1/2
    np.testing.assert_allclose(Q.adjacency_weight(res, 0, 2), 0.5)


def test_xi_dropped_real_summary():
    """A real SSumM run at an aggressive budget (further sparsification
    drops superedges) still satisfies the differential property."""
    src, dst, v = generate("ego-facebook", seed=3, scale=0.04)
    res = summarize(src, dst, v, SummaryConfig(T=6, k_frac=0.15, seed=3),
                    collect_history=False)
    assert res.num_supernodes > 1
    _assert_differential(res, check_dense_pagerank=False)


def test_real_summary_differential():
    src, dst, v = generate("ego-facebook", seed=2, scale=0.05)
    res = summarize(src, dst, v, SummaryConfig(T=6, k_frac=0.4, seed=2),
                    collect_history=False)
    _assert_differential(res)


def test_block_build_memoized():
    """Regression (ISSUE 8): two successive queries must not rebuild the
    O(|P|) block-space CSR — the build is memoized per SummaryResult."""
    rng = np.random.default_rng(11)
    res = _random_summary(rng)
    fresh = dataclasses.replace(res)  # drops the memo cache attribute
    before = Q.BLOCK_BUILDS
    Q.expected_degree(fresh, 0)
    Q.pagerank_summary(fresh)
    Q.triangle_density(fresh)
    Q.adjacency_weight(fresh, 0, 1)
    assert Q.BLOCK_BUILDS == before + 1
    # a distinct result object builds its own
    Q.expected_degree(dataclasses.replace(res), 0)
    assert Q.BLOCK_BUILDS == before + 2


def test_device_engine_reuses_host_memo():
    rng = np.random.default_rng(13)
    res = _random_summary(rng)
    fresh = dataclasses.replace(res)
    before = Q.BLOCK_BUILDS
    QueryEngine(fresh)
    QueryEngine(fresh)
    Q.expected_degree(fresh, 0)
    assert Q.BLOCK_BUILDS == before + 1


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_engine_accepts_plain_python_and_numpy_targets(dtype):
    rng = np.random.default_rng(5)
    res = _random_summary(rng)
    eng = QueryEngine(res)
    v = res.node2super.shape[0]
    one = eng.expected_degree(np.asarray([v - 1], dtype))
    assert one.shape == (1,) and one.dtype == np.float64
