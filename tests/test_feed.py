"""Out-of-core shard feeding (`repro.graphs.feed`, DESIGN.md §11):
layout math, padding, cache↔memory content identity, staging accounting,
and the corrupted-cache guard — all on the in-process single-device mesh
(multi-device equivalence runs in tests/feed_check.py)."""

import os
import shutil

import numpy as np
import pytest

from repro.graphs import generate, load_graph, write_edge_list
from repro.graphs.feed import (
    ShardFeeder,
    shard_edges,
    shard_edges_from_cache,
    shard_layout,
)
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# layout math (shared by both feed paths and the legacy shim)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e,n,want", [
    (0, 1, (0, 0)),
    (0, 8, (0, 0)),          # empty graph: zero-row shards
    (5, 8, (1, 8)),          # |E| < n_dev: three all-padding shards
    (8, 8, (1, 8)),
    (16, 8, (2, 16)),
    (17, 8, (3, 24)),        # |E| % n_dev != 0: part-padding last shard
    (1_000_003, 8, (125_001, 1_000_008)),
])
def test_shard_layout(e, n, want):
    assert shard_layout(e, n) == want
    rows, padded = shard_layout(e, n)
    # invariants the shard_map path depends on
    assert padded % n == 0 and padded - e < n and rows * n == padded


def test_shard_layout_rejects_bad_device_count():
    with pytest.raises(ValueError):
        shard_layout(10, 0)


# ---------------------------------------------------------------------------
# in-memory fallback: content identity with the historical padding
# ---------------------------------------------------------------------------


def test_shard_edges_matches_padded_edge_list(mesh):
    src, dst, v = generate("caida", scale=0.02)
    sh = shard_edges(src, dst, mesh)
    assert sh.num_edges == len(src) and sh.num_nodes is None
    assert np.array_equal(np.asarray(sh.src), np.asarray(src, np.int32))
    assert np.array_equal(np.asarray(sh.dst), np.asarray(dst, np.int32))
    assert sh.stats.path == "memory"


def test_shard_edges_rejects_ragged_columns(mesh):
    with pytest.raises(ValueError, match="equal-length"):
        shard_edges(np.arange(4), np.arange(5), mesh)


def test_feeder_buffer_is_not_aliased_across_feeds(mesh):
    """PJRT's CPU client adopts aligned host buffers zero-copy, so a feeder
    that reused one staging buffer in place would corrupt earlier feeds'
    device arrays (observed: a second feed overwrote the first's shards).
    Later feeds through a shared feeder must leave earlier results intact.

    The shards must sit *above* the CPU client's zero-copy adoption
    threshold (small buffers are always copied, which would make this
    test vacuous) — 2^17 int32 elements is comfortably adopted."""
    n = 1 << 17
    feeder = ShardFeeder()
    a = shard_edges(np.arange(n, dtype=np.int32),
                    np.arange(n, dtype=np.int32) + 1, mesh, feeder=feeder)
    b = shard_edges(np.full(n, 7, np.int32), np.full(n, 9, np.int32),
                    mesh, feeder=feeder)
    assert np.array_equal(np.asarray(a.src), np.arange(n, dtype=np.int32))
    assert np.array_equal(np.asarray(a.dst),
                          np.arange(n, dtype=np.int32) + 1)
    assert np.array_equal(np.asarray(b.src), np.full(n, 7, np.int32))
    # accounting: staging never exceeded the largest single shard
    assert a.stats.peak_staging_bytes == a.stats.shard_bytes
    assert feeder.peak_staging_bytes == a.stats.shard_bytes


# ---------------------------------------------------------------------------
# cache path: zero-densify identity with the in-memory path
# ---------------------------------------------------------------------------


def test_cache_feed_matches_memory_feed(tmp_path, mesh):
    src, dst, v = generate("ego-facebook", scale=0.05)
    p = write_edge_list(os.path.join(tmp_path, "g.txt"), src, dst, v,
                        shuffle=True, seed=3)
    g = load_graph(p)
    sh_mem = shard_edges(src, dst, mesh)
    sh_cache = shard_edges_from_cache(g.cache_dir, mesh)
    assert sh_cache.stats.path == "cache-mmap"
    assert sh_cache.num_nodes == v and sh_cache.num_edges == len(src)
    assert np.array_equal(np.asarray(sh_cache.src), np.asarray(sh_mem.src))
    assert np.array_equal(np.asarray(sh_cache.dst), np.asarray(sh_mem.dst))
    # the staging high-water mark is one shard, not 4·|E|
    assert sh_cache.stats.peak_staging_bytes == sh_cache.stats.shard_bytes


def test_run_distributed_rejects_mismatched_v(tmp_path, mesh):
    """Cache-fed shards carry |V| from meta.json; a stale caller-supplied
    v must fail loudly, not silently clamp edge ids inside jit."""
    from repro.core import SummaryConfig
    from repro.launch.summarize import run_distributed

    p = write_edge_list(os.path.join(tmp_path, "g.txt"),
                        [0, 1, 2], [1, 2, 3], 4)
    g = load_graph(p)
    shards = shard_edges_from_cache(g.cache_dir, mesh)
    with pytest.raises(ValueError, match=r"\|V\|=4"):
        run_distributed(None, None, 7, SummaryConfig(T=1), mesh,
                        shards=shards)


def test_cache_feed_refuses_incomplete_cache(tmp_path, mesh):
    p = write_edge_list(os.path.join(tmp_path, "g.txt"),
                        [0, 1, 2], [1, 2, 3], 4)
    g = load_graph(p)
    os.remove(os.path.join(g.cache_dir, "dst.npy"))
    with pytest.raises(FileNotFoundError, match="re-ingest"):
        shard_edges_from_cache(g.cache_dir, mesh)
    shutil.rmtree(g.cache_dir)
    with pytest.raises(FileNotFoundError):
        shard_edges_from_cache(g.cache_dir, mesh)


# ---------------------------------------------------------------------------
# process-spanning meshes: single-process feeds must refuse, loudly
# ---------------------------------------------------------------------------


def test_single_process_feeds_refuse_spanning_mesh(tmp_path, mesh,
                                                   monkeypatch):
    """`shard_edges`/`shard_edges_from_cache` stage every shard from one
    host — on a process-spanning mesh that silently assumed
    ``jax.process_count() == 1``. They must instead raise an error naming
    the multi-host entry point (a single-process CI cannot build a real
    spanning mesh, so the process census is monkeypatched)."""
    import repro.graphs.feed as feed_mod

    p = write_edge_list(os.path.join(tmp_path, "g.txt"),
                        [0, 1, 2], [1, 2, 3], 4)
    g = load_graph(p)
    monkeypatch.setattr(feed_mod, "mesh_process_count", lambda _mesh: 2)
    with pytest.raises(RuntimeError,
                       match="shard_edges_from_cache_multihost"):
        shard_edges_from_cache(g.cache_dir, mesh)
    with pytest.raises(RuntimeError,
                       match="shard_edges_from_cache_multihost"):
        shard_edges(np.asarray([0, 1], np.int32),
                    np.asarray([1, 2], np.int32), mesh)


def test_multihost_feed_degenerates_on_single_process(tmp_path, mesh):
    """On a 1-process mesh the multi-host entry point is the cache feed:
    same shards, same accounting, path stays "cache-mmap"."""
    from repro.graphs.feed import shard_edges_from_cache_multihost

    src, dst, v = generate("ego-facebook", scale=0.05)
    p = write_edge_list(os.path.join(tmp_path, "g.txt"), src, dst, v,
                        shuffle=True, seed=3)
    g = load_graph(p)
    a = shard_edges_from_cache(g.cache_dir, mesh)
    b = shard_edges_from_cache_multihost(g.cache_dir, mesh)
    assert b.stats.path == "cache-mmap"
    assert b.stats.process_count == 1
    assert b.stats.local_shards == a.stats.local_shards
    assert np.array_equal(np.asarray(a.src), np.asarray(b.src))
    assert np.array_equal(np.asarray(a.dst), np.asarray(b.dst))
