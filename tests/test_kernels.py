"""Per-kernel validation (deliverable c): every registry backend vs the
pure-jnp oracle, swept over shapes and operand regimes — plus the
kernel-dispatch registry's resolution rules (config > $SSUMM_KERNEL > ref).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref


def _compiled_pallas_available():
    """Compiled (non-interpret) Pallas needs a real accelerator backend."""
    return jax.default_backend() != "cpu"


# every backend the registry can resolve on this host
PARITY_BACKENDS = [
    b for b in kops.KERNEL_BACKENDS
    if b != "pallas" or _compiled_pallas_available()
]


def _operands(g, c, u, seed=0, dense=False):
    rng = np.random.default_rng(seed)
    lam = 2.0 if dense else 0.4
    m = rng.poisson(lam, size=(g, c, u)).astype(np.float32)
    n = rng.integers(1, 40, size=(g, c)).astype(np.float32)
    # some dead members (padding) — kernels must mask them out
    n[rng.random((g, c)) < 0.2] = 0.0
    s = rng.poisson(0.3, size=(g, c)).astype(np.float32)
    n_u = rng.integers(1, 40, size=(g, u)).astype(np.float32)
    cidx = rng.integers(0, u + 1, size=(g, c)).astype(np.int32)  # u = absent
    w = rng.poisson(0.2, size=(g, c, c)).astype(np.float32)
    w = np.maximum(w, np.swapaxes(w, 1, 2))
    np.einsum("gcc->gc", w)[...] = 0.0
    pi_row = n[..., None] * n_u[:, None, :]
    t = np.asarray(
        ref.pair_cost_ref(jnp.asarray(m), jnp.asarray(pi_row),
                          jnp.float32(60.0), jnp.float32(20.0))
    ).sum(-1) + 5.0
    return [jnp.asarray(x) for x in (m, n, s, t.astype(np.float32), n_u, cidx, w)]


# ---------------------------------------------------------------------------
# Kernel-dispatch registry resolution
# ---------------------------------------------------------------------------


def test_registry_default_is_ref(monkeypatch):
    monkeypatch.delenv(kops.ENV_VAR, raising=False)
    assert kops.resolve_kernel_backend(None) == "ref"


def test_registry_env_resolution(monkeypatch):
    monkeypatch.setenv(kops.ENV_VAR, "pallas-interpret")
    assert kops.resolve_kernel_backend(None) == "pallas-interpret"


def test_registry_config_beats_env(monkeypatch):
    monkeypatch.setenv(kops.ENV_VAR, "pallas-interpret")
    assert kops.resolve_kernel_backend("ref") == "ref"


@pytest.mark.parametrize("source", ["config", "env"])
def test_registry_unknown_backend_raises(monkeypatch, source):
    if source == "config":
        monkeypatch.delenv(kops.ENV_VAR, raising=False)
        with pytest.raises(ValueError) as exc:
            kops.resolve_kernel_backend("no-such-kernel")
    else:
        monkeypatch.setenv(kops.ENV_VAR, "no-such-kernel")
        with pytest.raises(ValueError) as exc:
            kops.resolve_kernel_backend(None)
    msg = str(exc.value)
    assert "no-such-kernel" in msg
    for name in kops.KERNEL_BACKENDS:  # error lists the valid set
        assert name in msg


def test_registry_backend_from_flags_compat():
    assert kops.backend_from_flags(False) == "ref"
    assert kops.backend_from_flags(True, interpret=True) == "pallas-interpret"
    assert kops.backend_from_flags(True, interpret=False) == "pallas"


def test_config_kernel_backend_reaches_dispatch(monkeypatch):
    from repro.core.types import SummaryConfig

    monkeypatch.setenv(kops.ENV_VAR, "no-such-kernel")
    # an explicit config value must win over a (broken) environment …
    assert kops.resolve_kernel_backend(
        SummaryConfig(kernel_backend="ref").kernel_backend) == "ref"
    # … and the default config defers to the environment
    with pytest.raises(ValueError):
        kops.resolve_kernel_backend(SummaryConfig().kernel_backend)


# ---------------------------------------------------------------------------
# Backend parity on the merge-gain / pair-cost fixtures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g,c,u", [(1, 4, 8), (3, 8, 16), (2, 16, 32), (5, 32, 64)])
@pytest.mark.parametrize("dense", [False, True])
def test_merge_gain_matches_oracle(g, c, u, dense):
    args = _operands(g, c, u, seed=g * 100 + u, dense=dense)
    cbar, log2v = jnp.float32(60.0), jnp.float32(20.0)
    rel_p, red_p = kops.merge_gain(*args, cbar, log2v,
                                   backend="pallas-interpret")
    rel_r, red_r = kops.merge_gain(*args, cbar, log2v, backend="ref")
    np.testing.assert_allclose(np.asarray(red_p), np.asarray(red_r),
                               rtol=1e-5, atol=1e-3)
    # rel contains -inf on invalid entries — compare masks then values
    mp, mr = np.isfinite(rel_p), np.isfinite(rel_r)
    np.testing.assert_array_equal(mp, mr)
    np.testing.assert_allclose(np.asarray(rel_p)[mp], np.asarray(rel_r)[mr],
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_merge_gain_all_backends_agree(backend):
    """Every resolvable registry backend vs the jnp oracle, one fixture."""
    args = _operands(3, 8, 16, seed=11)
    cbar, log2v = jnp.float32(60.0), jnp.float32(20.0)
    rel_r, red_r = ref.merge_gain_ref(*args, cbar, log2v)
    rel_b, red_b = kops.merge_gain(*args, cbar, log2v, backend=backend)
    np.testing.assert_allclose(np.asarray(red_b), np.asarray(red_r),
                               rtol=1e-5, atol=1e-3)
    mb, mr = np.isfinite(rel_b), np.isfinite(rel_r)
    np.testing.assert_array_equal(mb, mr)
    np.testing.assert_allclose(np.asarray(rel_b)[mb], np.asarray(rel_r)[mr],
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("e", [7, 128, 1024, 1025, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_pair_cost_matches_oracle(e, dtype):
    rng = np.random.default_rng(e)
    cnt = rng.poisson(1.0, size=e).astype(np.float32)
    pi = (cnt + rng.integers(0, 30, size=e)).astype(np.float32)
    cnt_j = jnp.asarray(cnt).astype(dtype)
    pi_j = jnp.asarray(pi).astype(dtype)
    cbar, log2v = jnp.float32(45.0), jnp.float32(14.0)
    got = kops.pair_cost(cnt_j, pi_j, cbar, log2v,
                         backend="pallas-interpret")
    want = ref.pair_cost_ref(cnt_j, pi_j, cbar, log2v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_pair_cost_all_backends_agree(backend):
    rng = np.random.default_rng(42)
    cnt = jnp.asarray(rng.poisson(1.0, size=512).astype(np.float32))
    pi = cnt + jnp.asarray(rng.integers(0, 30, size=512).astype(np.float32))
    cbar, log2v = jnp.float32(45.0), jnp.float32(14.0)
    want = ref.pair_cost_ref(cnt, pi, cbar, log2v)
    got = kops.pair_cost(cnt, pi, cbar, log2v, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_merge_gain_symmetry():
    """Reduction(A,B) must equal Reduction(B,A) (unordered merges)."""
    args = _operands(2, 8, 16, seed=7)
    rel, red = kops.merge_gain(*args, jnp.float32(60.0), jnp.float32(20.0),
                               backend="pallas-interpret")
    red = np.asarray(red)
    np.testing.assert_allclose(red, np.swapaxes(red, 1, 2), rtol=1e-5,
                               atol=1e-3)


def test_merge_gain_self_pairs_invalid():
    args = _operands(1, 6, 8, seed=3)
    rel, _ = kops.merge_gain(*args, jnp.float32(60.0), jnp.float32(20.0),
                             backend="pallas-interpret")
    diag = np.einsum("gcc->gc", np.asarray(rel))
    assert np.all(np.isneginf(diag))
