"""Property tests (hypothesis) for the MDL cost machinery + exactness of the
closed-form evaluation against dense brute force (Eqs. 2/4/9/10/11)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import costs
from repro.core.ref_numpy import SSumMRef, _entropy_bits
from repro.core.types import SummaryState, init_state, make_graph
from repro.core import evaluate as ev

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# entropy / encoding properties
# ---------------------------------------------------------------------------


@given(cnt=st.integers(0, 1000), pi=st.integers(0, 1000))
@settings(**SETTINGS)
def test_entropy_bits_bounds(cnt, pi):
    """0 ≤ Cost₍₁₎−C̄ ≤ |Π| bits (entropy of a Bernoulli ≤ 1 bit/slot)."""
    got = float(costs.entropy_bits(jnp.float32(cnt), jnp.float32(pi)))
    assert got >= 0.0
    assert got <= max(pi, 0) + 1e-3
    if 0 < cnt < pi:
        want = _entropy_bits(cnt, pi)
        assert math.isclose(got, want, rel_tol=1e-5, abs_tol=1e-3)
    else:
        assert got == 0.0


@given(cnt=st.integers(1, 500), extra=st.integers(0, 500),
       cbar=st.floats(1.0, 100.0), log2v=st.floats(2.0, 30.0))
@settings(**SETTINGS)
def test_pair_cost_star_is_min(cnt, extra, cbar, log2v):
    pi = cnt + extra
    c1 = cbar + float(costs.entropy_bits(jnp.float32(cnt), jnp.float32(pi)))
    c2 = 2.0 * cnt * log2v
    got = float(costs.pair_cost_star(jnp.float32(cnt), jnp.float32(pi),
                                     jnp.float32(cbar), jnp.float32(log2v)))
    assert math.isclose(got, min(c1, c2), rel_tol=1e-5, abs_tol=1e-3)


@given(st.data())
@settings(**SETTINGS)
def test_keep_decision_consistent_with_costs(data):
    cnt = data.draw(st.integers(1, 200))
    pi = cnt + data.draw(st.integers(0, 400))
    cbar = data.draw(st.floats(1.0, 80.0))
    log2v = data.draw(st.floats(2.0, 24.0))
    keep = bool(costs.keep_superedge(jnp.float32(cnt), jnp.float32(pi),
                                     jnp.float32(cbar), jnp.float32(log2v),
                                     re_guard=0))
    c1 = cbar + float(costs.entropy_bits(jnp.float32(cnt), jnp.float32(pi)))
    c2 = 2.0 * cnt * log2v
    assert keep == (c1 < c2)


# ---------------------------------------------------------------------------
# closed-form evaluation == dense brute force
# ---------------------------------------------------------------------------


def _random_graph_and_partition(rng, v, e_target, n_groups):
    src = rng.integers(0, v, e_target)
    dst = rng.integers(0, v, e_target)
    keep = src != dst
    graph, _ = make_graph(src[keep], dst[keep], v)
    n2s_group = rng.integers(0, n_groups, v)
    # canonical representative ids (supernode id = min member id)
    reps = np.full(n_groups, -1, np.int64)
    n2s = np.zeros(v, np.int64)
    for u in range(v):
        g = n2s_group[u]
        if reps[g] < 0:
            reps[g] = u
        n2s[u] = reps[g]
    return graph, n2s


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_metrics_match_dense_bruteforce(seed):
    rng = np.random.default_rng(seed)
    v = 40
    graph, n2s = _random_graph_and_partition(rng, v, 160, 12)
    e = graph.num_edges
    size = np.bincount(n2s, minlength=v)
    state = SummaryState(
        node2super=jnp.asarray(n2s, jnp.int32),
        size=jnp.asarray(size, jnp.int32),
        rng=jnp.zeros((2,), jnp.uint32),
        t=jnp.asarray(1, jnp.int32),
    )
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    m = costs.summary_metrics(pt, state, v, e, cbar_mode="paper", re_guard=1)

    # --- dense reconstruction with the same keep decisions ---------------
    keep = np.asarray(m["keep"])
    lo = np.asarray(pt.lo)[keep]
    hi = np.asarray(pt.hi)[keep]
    w = np.asarray(pt.cnt)[keep].astype(np.int64)
    from repro.core.types import SummaryResult

    res = SummaryResult(
        node2super=n2s.astype(np.int32), super_size=size.astype(np.int32),
        edge_lo=lo, edge_hi=hi, edge_w=w,
        num_supernodes=int((size > 0).sum()), num_superedges=len(w),
        size_bits=0.0, input_size_bits=0.0, re1=0.0, re2=0.0, mdl_cost=0.0,
        iterations_run=0,
    )
    a = ev.dense_adjacency(np.asarray(graph.src), np.asarray(graph.dst), v)
    a_hat = ev.reconstruct_dense(res)
    np.testing.assert_allclose(float(m["re1"]), ev.re_p_dense(a, a_hat, 1),
                               rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(float(m["re2"]), ev.re_p_dense(a, a_hat, 2),
                               rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(float(m["size_bits"]),
                               ev.summary_size_bits_dense(res), rtol=1e-5)


# ---------------------------------------------------------------------------
# Lemma 3.1 (2-hop merger bound) on the sequential oracle's exact costs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lemma_31_reduction_bound(seed):
    rng = np.random.default_rng(seed)
    v = 24
    src = rng.integers(0, v, 60)
    dst = rng.integers(0, v, 60)
    keep = src != dst
    ref = SSumMRef(src[keep], dst[keep], v, cbar_mode="paper", re_guard=0)
    cbar = ref._cbar()
    checked = 0
    for a in range(v):
        for b in ref.adj[a]:  # 1-hop pairs are within 2 hops
            if a >= b:
                continue
            cost_a = ref.supernode_cost(a, cbar)
            cost_b = ref.supernode_cost(b, cbar)
            cost_ab = ref.pair_cost(float(ref.adj[a].get(b, 0)),
                                    ref._pi(a, b), cbar)
            reduction = (cost_a + cost_b - cost_ab) - ref.merged_cost(a, b, cbar)
            assert reduction <= min(cost_a, cost_b) + 1e-6
            checked += 1
    assert checked > 0


@pytest.mark.parametrize("seed", [0, 1])
def test_lemma_32_far_pairs_bound(seed):
    """Mergers of ≥3-hop-apart supernodes reduce cost by ≤ C̄ (Lemma 3.2)."""
    rng = np.random.default_rng(seed)
    v = 30
    # two disconnected cliques => cross pairs are infinitely far apart
    edges = []
    for base in (0, 15):
        for i in range(base, base + 8):
            for j in range(i + 1, base + 8):
                if rng.random() < 0.6:
                    edges.append((i, j))
    src, dst = np.array([e[0] for e in edges]), np.array([e[1] for e in edges])
    ref = SSumMRef(src, dst, v, cbar_mode="paper", re_guard=0)
    cbar = ref._cbar()
    cbar_bound = 2 * ref.log2v + ref.log2e
    for a in range(0, 8):
        for b in range(15, 23):
            cost_a = ref.supernode_cost(a, cbar)
            cost_b = ref.supernode_cost(b, cbar)
            reduction = (cost_a + cost_b) - ref.merged_cost(a, b, cbar)
            assert reduction <= cbar_bound + 1e-6
