"""Blockwise (flash-style) attention vs the dense reference, plus the
collective-bytes HLO parser used by the roofline extractor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _mask, mha
from repro.models.flash import _fit_block, blockwise_attention


def _rand_qkv(b, s, t, h, k, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((b, t, k, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, k, hd)), jnp.float32)
    return q, kk, v


@pytest.mark.parametrize("s,t,h,k,window", [
    (32, 32, 4, 4, None),      # MHA causal
    (64, 64, 8, 2, None),      # GQA causal
    (64, 64, 4, 4, 16),        # sliding window
    (30, 30, 4, 2, None),      # non-power-of-two (whisper-style)
])
def test_blockwise_matches_dense(s, t, h, k, window):
    q, kk, v = _rand_qkv(2, s, t, h, k, 16, seed=s)
    got = blockwise_attention(q, kk, v, causal=True, window=window,
                              q_block=8, kv_block=16)
    mask = _mask(jnp.arange(s), jnp.arange(t), True, window)
    want = mha(q, kk, v, mask).reshape(2, s, h, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_noncausal():
    q, kk, v = _rand_qkv(1, 24, 24, 2, 2, 8, seed=7)
    got = blockwise_attention(q, kk, v, causal=False, q_block=8, kv_block=8)
    mask = jnp.zeros((24, 24), jnp.float32)
    want = mha(q, kk, v, mask).reshape(1, 24, 2, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_fit_block():
    assert _fit_block(1500, 256) == 250
    assert _fit_block(1024, 256) == 256
    assert _fit_block(100, 256) == 100
    assert _fit_block(7, 4) == 1  # prime: falls back to 1
    for n, want in ((1500, 256), (4096, 1024), (1500, 1024)):
        b = _fit_block(n, want)
        assert n % b == 0 and b <= want


def test_blockwise_grad_finite():
    q, kk, v = _rand_qkv(1, 16, 16, 2, 2, 8)

    def loss(q):
        out = blockwise_attention(q, kk, v, causal=True, q_block=8,
                                  kv_block=8)
        return jnp.sum(out * out)

    g = jax.grad(loss)(q)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.sum(jnp.abs(g))) > 0


# ---------------------------------------------------------------------------
# roofline collective parser
# ---------------------------------------------------------------------------


def test_collective_bytes_parser():
    from repro.launch.costs import collective_bytes

    hlo = """
  %ar = f32[2,1024]{1,0} all-reduce(f32[2,1024]{1,0} %x), replica_groups={}
  %ag = (bf16[4,256]{1,0}, bf16[4,256]{1,0}) all-gather-start(bf16[4,256]{1,0} %y)
  %aa = f32[8,16]{1,0} all-to-all(f32[8,16]{1,0} %z)
  %cp = u32[128]{0} collective-permute(u32[128]{0} %w)
  %rs = bf16[512]{0} reduce-scatter(bf16[1024]{0} %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2 * 2 * 1024 * 4  # ring factor 2
    assert out["all-to-all"] == 8 * 16 * 4
    assert out["collective-permute"] == 128 * 4
    assert out["total"] > 0
