"""Subprocess body for preemption-safety tests (run via
tests/test_preemption.py, never imported by pytest).

Each mode exercises one leg of the PreemptionGuard contract against the
real engine on a small synthetic graph:

  golden  — uninterrupted run; prints the final metrics JSON.
  term    — SIGTERM already pending when the run starts: the flag must be
            observed at the *first* host-sync point, the state saved
            synchronously, and the process exit ``RESUMABLE_EXIT`` after
            printing ``{"preempted": true, "step": N}``.
  int     — same, for SIGINT.
  double  — two SIGTERMs: the second must hard-exit from the handler with
            the shell convention ``128 + SIGTERM`` — no save, no
            traceback, at worst an ignored ``.tmp-`` directory.
  resume  — continue from the latest committed checkpoint in ``--dir``;
            prints metrics JSON plus ``resumed_from``. Bit-identity with
            ``golden`` is asserted by the pytest side.

The self-signal (``os.kill`` on our own pid) makes delivery deterministic:
no parent/child race over whether the run finished before the signal
landed. Parent-delivered signals are exercised end-to-end against the real
launcher by tests/chaos_check.py.
"""

import argparse
import json
import os
import signal
import time

import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.engine import EngineCheckpointer
from repro.runtime import (
    RESUMABLE_EXIT,
    CheckpointManager,
    Preempted,
    PreemptionGuard,
)

CFG = SummaryConfig(T=8, k_frac=0.2, seed=0, driver_chunk=2)


def _problem():
    rng = np.random.default_rng(0)
    v, e = 400, 1600
    return rng.integers(0, v, e), rng.integers(0, v, e), v


def _metrics(res) -> dict:
    return {
        "size_bits": res.size_bits,
        "re1": res.re1,
        "re2": res.re2,
        "num_supernodes": res.num_supernodes,
        "num_superedges": res.num_superedges,
        "iterations_run": res.iterations_run,
        "node2super_sum": int(np.sum(res.node2super)),
        "edge_w_sum": int(np.sum(res.edge_w)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("mode",
                    choices=["golden", "term", "int", "double", "resume"])
    ap.add_argument("--dir", required=True)
    args = ap.parse_args()
    src, dst, v = _problem()

    if args.mode == "golden":
        print(json.dumps(_metrics(summarize(src, dst, v, CFG))))
        return

    guard = PreemptionGuard()
    ck = EngineCheckpointer(
        manager=CheckpointManager(args.dir, keep=3), every=1, guard=guard)

    if args.mode in ("term", "int"):
        signum = signal.SIGTERM if args.mode == "term" else signal.SIGINT
        os.kill(os.getpid(), signum)
        try:
            summarize(src, dst, v, CFG, checkpointer=ck)
        except Preempted as p:
            print(json.dumps({"preempted": True, "step": p.step}))
            raise SystemExit(RESUMABLE_EXIT)
        raise SystemExit("pending signal was never observed at a sync point")

    if args.mode == "double":
        os.kill(os.getpid(), signal.SIGTERM)
        time.sleep(0.2)  # first handler sets the cooperative flag
        os.kill(os.getpid(), signal.SIGTERM)
        time.sleep(2.0)  # second handler must os._exit before this returns
        raise SystemExit("second signal did not hard-exit")

    if args.mode == "resume":
        res = summarize(src, dst, v, CFG, checkpointer=ck, resume=True)
        print(json.dumps(dict(_metrics(res), resumed_from=res.resumed_from)))


if __name__ == "__main__":
    main()
