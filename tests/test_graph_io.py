"""Streaming edge-list ingestion tests (`repro.graphs.io`, DESIGN.md §10):
parser edge cases, cache identity/invalidation, the chunk-bounded memory
contract, and loader-vs-`generate` equivalence down to the sparsify mask."""

import gzip
import hashlib
import json
import os
import tracemalloc

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SummaryConfig, costs, sparsify, summarize
from repro.core.types import SummaryState, make_graph
from repro.graphs import generate, load_graph, open_csr, write_edge_list
from repro.graphs.io import (
    DATA_DIR_ENV,
    IngestStats,
    ingest_edge_list,
    iter_edge_chunks,
    load_cache,
)

from repro.graphs.io import CACHE_MEMBERS as CACHE_FILES


def _write(tmp_path, text, name="g.txt"):
    p = os.path.join(tmp_path, name)
    if name.endswith(".gz"):
        with gzip.open(p, "wt") as f:
            f.write(text)
    else:
        with open(p, "w") as f:
            f.write(text)
    return p


def _edges(g):
    return np.asarray(g.src).tolist(), np.asarray(g.dst).tolist()


def _cache_digest(cache_dir):
    h = hashlib.sha256()
    for fn in CACHE_FILES:
        with open(os.path.join(cache_dir, fn), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# parser edge cases
# ---------------------------------------------------------------------------


def test_comments_whitespace_and_header(tmp_path):
    p = _write(tmp_path, "# SNAP-ish preamble\n"
                         "% matrix-market style comment\n"
                         "# Nodes: 6 Edges: 3\n"
                         "\n"
                         "0\t1\n"
                         "  2   3  \n"
                         "1 2\n")
    g = load_graph(p)
    assert g.source == "real"
    assert g.num_nodes == 6  # header counts the isolated nodes 4, 5
    assert _edges(g) == ([0, 1, 2], [1, 2, 3])
    assert g.stats.comment_lines == 3
    assert g.stats.header_nodes == 6
    assert not g.stats.relabeled


def test_one_indexed_and_noncontiguous_ids_relabel_dense(tmp_path):
    # ids {1, 5, 900, 7000}: loader must relabel by sorted original id
    p = _write(tmp_path, "7000 900\n1 5\n900 1\n")
    g = load_graph(p)
    assert g.stats.relabeled
    assert g.num_nodes == 4
    assert _edges(g) == ([0, 0, 2], [1, 2, 3])


def test_one_indexed_full_range(tmp_path):
    # 1..V contiguous (classic 1-indexed export): dense map is id-1
    p = _write(tmp_path, "1 2\n2 3\n3 1\n")
    g = load_graph(p)
    assert g.num_nodes == 3
    assert _edges(g) == ([0, 0, 1], [1, 2, 2])


def test_duplicates_reversed_and_self_loops(tmp_path):
    p = _write(tmp_path, "0 1\n1 0\n0 1\n2 2\n1 2\n2 1\n")
    g = load_graph(p)
    assert _edges(g) == ([0, 1], [1, 2])
    assert g.stats.self_loops_dropped == 1
    assert g.stats.duplicates_dropped == 3


def test_extra_columns_and_csv(tmp_path):
    # third column (weight/timestamp) is ignored; commas == whitespace
    p = _write(tmp_path, "0,1\n1,2\n", name="g.csv")
    q = _write(tmp_path, "0 1 17 999\n1 2 3\n", name="w.txt")
    assert _edges(load_graph(p)) == _edges(load_graph(q)) == ([0, 1], [1, 2])


def test_mixed_column_counts_never_mispair(tmp_path):
    # '0 1 7' + '2 3': aggregate token counts must not pair fields across
    # rows — the third column is per-row noise, not a node id
    p = _write(tmp_path, "0 1 7\n2 3\n")
    assert _edges(load_graph(p)) == ([0, 2], [1, 3])
    q = _write(tmp_path, "0 1 7\n3\n", name="bad.txt")
    with pytest.raises(ValueError, match="malformed"):
        load_graph(q)


def test_ids_beyond_int31_rejected(tmp_path):
    p = _write(tmp_path, f"5 {1 << 31}\n")
    with pytest.raises(ValueError, match="2\\^31"):
        load_graph(p)


def test_empty_file(tmp_path):
    p = _write(tmp_path, "# Nodes: 0 Edges: 0\n")
    g = load_graph(p)
    assert g.num_nodes == 0 and g.num_edges == 0
    indptr, indices = open_csr(g.cache_dir)
    assert indptr.shape == (1,) and indices.shape == (0,)


def test_gzip_vs_plain_bit_identical_cache(tmp_path):
    src, dst, v = generate("caida", scale=0.02)
    a = write_edge_list(os.path.join(tmp_path, "a.txt"), src, dst, v,
                        shuffle=True, seed=5)
    b = write_edge_list(os.path.join(tmp_path, "b.txt.gz"), src, dst, v,
                        shuffle=True, seed=5)
    ga, gb = load_graph(a), load_graph(b)
    assert ga.num_nodes == gb.num_nodes == v
    assert _cache_digest(ga.cache_dir) == _cache_digest(gb.cache_dir)


# ---------------------------------------------------------------------------
# cache behavior
# ---------------------------------------------------------------------------


def test_cache_hit_parses_zero_bytes_and_refresh_reparses(tmp_path):
    p = _write(tmp_path, "0 1\n1 2\n")
    g1 = load_graph(p)
    assert g1.source == "real" and g1.stats.bytes_parsed > 0
    g2 = load_graph(p)
    assert g2.source == "cache" and g2.stats.bytes_parsed == 0
    assert _edges(g1) == _edges(g2)
    g3 = load_graph(p, refresh=True)
    assert g3.source == "real" and g3.stats.bytes_parsed > 0


def test_corrupted_cache_missing_member_reingests(tmp_path):
    """meta.json intact but a ``.npy`` member lost (mid-write crash,
    partial deletion): ``load_graph`` must fall through to re-ingestion
    instead of raising at ``np.load`` time — for every member."""
    from repro.graphs.io import cache_is_fresh

    p = _write(tmp_path, "0 1\n1 2\n2 3\n")
    for member in CACHE_FILES:
        g = load_graph(p)
        assert os.path.exists(os.path.join(g.cache_dir, "meta.json"))
        os.remove(os.path.join(g.cache_dir, member))
        assert not cache_is_fresh(g.cache_dir, p)
        g2 = load_graph(p)  # re-parses and rebuilds the full member set
        assert g2.source == "real" and g2.stats.bytes_parsed > 0
        assert _edges(g2) == ([0, 1, 2], [1, 2, 3])
        assert all(os.path.exists(os.path.join(g2.cache_dir, m))
                   for m in CACHE_FILES)
    # registry-name resolution skips a corrupted cache too (no source file)
    assert cache_is_fresh(g2.cache_dir)
    os.remove(os.path.join(g2.cache_dir, "indices.npy"))
    assert not cache_is_fresh(g2.cache_dir)


def test_corrupted_cache_truncated_member_reingests(tmp_path):
    """A member *present but short* (disk-full writer, torn copy) must be
    caught too: mmap-loading a truncated blob either raises later or —
    worse — silently serves zeros. ``cache_is_fresh`` checks every
    member's npy header dtype/shape against meta.json and its exact
    on-disk byte size, so a truncated cache falls through to re-ingestion."""
    from repro.graphs.io import cache_is_fresh

    p = _write(tmp_path, "".join(f"{i} {i + 1}\n" for i in range(64)))
    for member in CACHE_FILES:
        g = load_graph(p)
        assert cache_is_fresh(g.cache_dir, p)
        blob = os.path.join(g.cache_dir, member)
        with open(blob, "r+b") as f:
            f.truncate(os.path.getsize(blob) - 4)
        assert not cache_is_fresh(g.cache_dir, p), member
        g2 = load_graph(p)
        assert g2.source == "real" and g2.stats.bytes_parsed > 0, member
        assert _edges(g2) == (list(range(64)), list(range(1, 65)))
    # grown blobs (appended garbage) and dtype swaps are stale as well
    g = load_graph(p)
    blob = os.path.join(g.cache_dir, "src.npy")
    with open(blob, "ab") as f:
        f.write(b"\x00" * 8)
    assert not cache_is_fresh(g.cache_dir, p)
    g = load_graph(p)
    np.save(os.path.join(g.cache_dir, "indptr.npy"),
            np.load(os.path.join(g.cache_dir, "indptr.npy")
                    ).astype(np.int32))
    assert not cache_is_fresh(g.cache_dir, p)


def test_cache_invalidated_when_file_changes(tmp_path):
    p = _write(tmp_path, "0 1\n")
    g1 = load_graph(p)
    assert g1.num_edges == 1
    _write(tmp_path, "0 1\n1 2\n5 0\n")
    os.utime(p, ns=(0, 0))  # force a distinct mtime stamp
    g2 = load_graph(p)
    assert g2.source == "real" and g2.num_edges == 3


def test_chunk_size_does_not_change_the_cache(tmp_path):
    src, dst, v = generate("caida", scale=0.05)
    p = write_edge_list(os.path.join(tmp_path, "g.txt"), src, dst, v,
                        shuffle=True, dup_frac=0.2, self_loops=9, seed=2)
    digests = set()
    for chunk in (64, 977, 1 << 20):
        cdir = ingest_edge_list(p, os.path.join(tmp_path, f"c{chunk}"),
                                chunk_edges=chunk)
        digests.add(_cache_digest(cdir))
    assert len(digests) == 1


def test_cache_loads_via_mmap(tmp_path):
    p = _write(tmp_path, "0 1\n1 2\n0 2\n")
    load_graph(p)
    g = load_graph(p)
    assert isinstance(g.src, np.memmap) and isinstance(g.dst, np.memmap)
    indptr, indices = open_csr(g.cache_dir)
    assert isinstance(indptr, np.memmap) and isinstance(indices, np.memmap)


def test_csr_matches_edge_list(tmp_path):
    src, dst, v = generate("ego-facebook", scale=0.05)
    p = write_edge_list(os.path.join(tmp_path, "g.txt"), src, dst, v,
                        shuffle=True, seed=4)
    g = load_graph(p, chunk_edges=123)
    indptr, indices = open_csr(g.cache_dir)
    deg = np.bincount(np.concatenate([src, dst]), minlength=v)
    assert np.array_equal(np.diff(indptr), deg)
    adj = {(int(a), int(b)) for a, b in zip(src, dst)}
    for u in (0, 1, v // 2, v - 1):
        nbrs = set(np.asarray(indices[indptr[u]:indptr[u + 1]]).tolist())
        want = {b for a, b in adj if a == u} | {a for a, b in adj if b == u}
        assert nbrs == want


# ---------------------------------------------------------------------------
# bounded memory
# ---------------------------------------------------------------------------


def test_parser_memory_bounded_by_chunk_size(tmp_path):
    src, dst, v = generate("amazon0302", scale=0.12)  # ~100k raw edges
    p = write_edge_list(os.path.join(tmp_path, "big.txt"), src, dst, v,
                        shuffle=True, seed=6)
    e = len(src)
    assert e > 80_000
    chunk = 2048
    tracemalloc.start()
    ingest_edge_list(p, os.path.join(tmp_path, "cache"), chunk_edges=chunk)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    stats = IngestStats()
    rows = [len(s) for s, _ in iter_edge_chunks(p, chunk, stats)]
    # chunking is byte-driven (sizehint ≈ chunk·24B): short lines overshoot
    # the row target by a constant factor, never by O(|E|)
    assert stats.chunks >= 8
    assert stats.max_chunk_rows == max(rows) <= 8 * chunk
    assert stats.max_chunk_rows < e // 4
    # the design bound is O(chunk + |V|): id/degree tables are |V|-sized
    # by contract, per-chunk python token lists cost ~hundreds of bytes a
    # row. A non-streaming parse holds |E| token lists (~170 B each,
    # ≈ 16 MB here) — the chunked path must stay several× below that.
    assert peak < 6 * 8 * v + 1000 * chunk  # ≈ 5 MB here
    assert peak < 60 * e  # ≈ 3× under the naive whole-file watermark


def test_chunk_iterator_respects_byte_budget(tmp_path):
    p = _write(tmp_path, "".join(f"{i} {i+1}\n" for i in range(10_000)))
    stats = IngestStats()
    rows = [len(s) for s, _ in iter_edge_chunks(p, 100, stats)]
    assert sum(rows) == 10_000
    assert max(rows) <= 800  # 100·24B hint / ~6B lines, plus one readahead


# ---------------------------------------------------------------------------
# registry resolution + equivalence with the in-memory path
# ---------------------------------------------------------------------------


def test_registry_synthetic_fallback(monkeypatch):
    monkeypatch.delenv(DATA_DIR_ENV, raising=False)
    g = load_graph("caida", scale=0.02, seed=3)
    src, dst, v = generate("caida", seed=3, scale=0.02)
    assert g.source == "synthetic" and g.num_nodes == v
    assert np.array_equal(np.asarray(g.src), src)


def test_registry_resolves_data_dir_first(tmp_path, monkeypatch):
    src, dst, v = generate("caida", scale=0.02)
    write_edge_list(os.path.join(tmp_path, "caida.txt.gz"), src, dst, v,
                    seed=1)
    monkeypatch.setenv(DATA_DIR_ENV, str(tmp_path))
    g = load_graph("caida", scale=0.5)  # scale must not apply to real files
    assert g.source == "real" and g.num_nodes == v and g.num_edges == len(src)
    assert load_graph("caida").source == "cache"


def test_registry_cache_serves_after_source_file_removed(tmp_path,
                                                         monkeypatch):
    src, dst, v = generate("caida", scale=0.02)
    p = write_edge_list(os.path.join(tmp_path, "caida.txt.gz"), src, dst, v,
                        seed=1)
    monkeypatch.setenv(DATA_DIR_ENV, str(tmp_path))
    assert load_graph("caida").source == "real"
    os.remove(p)  # resolution order leg 2: cache outlives the text file
    g = load_graph("caida")
    assert g.source == "cache" and g.num_edges == len(src)
    assert g.stats.bytes_parsed == 0


def test_unknown_name_raises(monkeypatch):
    monkeypatch.delenv(DATA_DIR_ENV, raising=False)
    with pytest.raises(FileNotFoundError):
        load_graph("no-such-dataset")


def test_loader_matches_generate_bit_identical(tmp_path):
    src, dst, v = generate("ego-facebook", scale=0.05)
    p = write_edge_list(os.path.join(tmp_path, "g.txt.gz"), src, dst, v,
                        shuffle=True, dup_frac=0.1, self_loops=7, seed=9)
    g = load_graph(p, chunk_edges=500)
    assert g.num_nodes == v
    assert np.array_equal(np.asarray(g.src), src)
    assert np.array_equal(np.asarray(g.dst), dst)


def test_loader_vs_generate_same_further_sparsify_output(tmp_path):
    """Same edge set through the file loader and through ``generate`` must
    produce the same drop mask and post-sparsify metrics (and the same
    end-to-end summary), per the PR acceptance criterion."""
    src, dst, v = generate("ego-facebook", scale=0.05, seed=1)
    p = write_edge_list(os.path.join(tmp_path, "g.txt"), src, dst, v,
                        shuffle=True, seed=11)
    g = load_graph(p, chunk_edges=700)

    cfg = SummaryConfig(T=4, k_frac=0.4, seed=1, ensure_budget=False)
    res_mem = summarize(src, dst, v, cfg)
    res_io = summarize(np.asarray(g.src), np.asarray(g.dst), g.num_nodes, cfg)
    assert res_mem.size_bits == res_io.size_bits
    assert res_mem.re1 == res_io.re1
    assert np.array_equal(res_mem.node2super, res_io.node2super)
    assert np.array_equal(res_mem.edge_lo, res_io.edge_lo)
    assert np.array_equal(res_mem.edge_w, res_io.edge_w)

    # direct further_sparsify on the merged partition, both edge sources
    state = SummaryState(node2super=jnp.asarray(res_mem.node2super),
                         size=jnp.asarray(res_mem.super_size),
                         rng=jnp.zeros((2,), jnp.uint32),
                         t=jnp.asarray(4, jnp.int32))
    outs = []
    for s, d, n in ((src, dst, v),
                    (np.asarray(g.src), np.asarray(g.dst), g.num_nodes)):
        graph, _ = make_graph(s, d, n)
        pt = costs.build_pair_table(graph.src, graph.dst, state)
        drop, after = sparsify.further_sparsify(
            pt, state, n, graph.num_edges,
            k_bits=0.35 * res_mem.input_size_bits)
        outs.append((np.asarray(drop),
                     {k: float(x) for k, x in after.items()
                      if np.ndim(x) == 0}))
    assert np.array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]


def test_loader_meta_records_provenance(tmp_path):
    p = _write(tmp_path, "0 1\n1 2\n1 0\n")
    g = load_graph(p)
    with open(os.path.join(g.cache_dir, "meta.json")) as f:
        meta = json.load(f)
    assert meta["num_edges"] == 2
    assert meta["source"]["name"] == "g.txt"
    assert meta["stats"]["duplicates_dropped"] == 1
    # load_cache round-trips the recorded stats flags
    assert load_cache(g.cache_dir).stats.relabeled == meta["relabeled"]
