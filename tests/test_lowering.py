"""Lowering machinery on the in-process 1-device mesh: every smoke arch ×
shape kind builds a cell and lowers without allocation (the 512-device
production meshes are exercised by launch/dryrun.py in a subprocess)."""

import dataclasses

import jax
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.lowering import build_cell, lower_cell
from repro.launch.mesh import make_host_mesh

SMOKE_SHAPES = [
    ShapeSpec("smoke_train", 32, 4, "train"),
    ShapeSpec("smoke_prefill", 64, 2, "prefill"),
    ShapeSpec("smoke_decode", 64, 4, "decode"),
]


def _mesh():
    return make_host_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("sp", SMOKE_SHAPES, ids=lambda s: s.name)
def test_lower_cell_smoke(arch, sp):
    cfg = get_smoke_config(arch)
    from repro.configs.base import SHAPES

    SHAPES[sp.name] = sp  # register the reduced shape for input_specs
    try:
        cell = build_cell(cfg, sp.name, _mesh())
        lowered = lower_cell(cell, donate=False)
        text = lowered.as_text()
        assert "module @jit_step" in text  # StableHLO lowering produced
    finally:
        SHAPES.pop(sp.name, None)


def test_cell_shardings_cover_all_params():
    cfg = get_smoke_config("gemma_7b")
    cell = build_cell(cfg, _shape(), _mesh())
    p_shard = cell.arg_shardings[0]
    n_params = len(jax.tree.leaves(cell.arg_structs[0]))
    n_shardings = len(jax.tree.leaves(
        p_shard, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_shardings


def _shape():
    from repro.configs.base import SHAPES

    sp = ShapeSpec("smoke_train2", 32, 4, "train")
    SHAPES[sp.name] = sp
    return sp.name
