"""Subprocess body for out-of-core feed tests (needs its own jax init with
fake devices — run via tests/test_distributed.py, never imported by pytest).

Checks, on an 8-device host mesh (DESIGN.md §11):
  1. equivalence: `shard_edges_from_cache` → `run_distributed` produces
     merge/sparsify metrics **bit-identical** to the in-memory
     `pad_and_shard_edges` path (and to the historical replicated-array
     construction that let jit reshard) — on a graph whose |E| is *not*
     divisible by the device count;
  2. shard boundaries: with |E| < n_dev the trailing shards are pure
     ``-1`` padding, per-device contents match the exact mmap slices, and
     the all-padding shards flow through a full merge round + metric
     parity with the single-device closed forms;
  3. staging accounting: the feed's host high-water mark is one shard,
     never 4·|E|.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SummaryConfig, costs
from repro.core.distributed import make_distributed_step, pad_and_shard_edges
from repro.core.types import init_state, make_graph
from repro.graphs import generate, load_graph, write_edge_list
from repro.graphs.feed import ShardFeeder, shard_edges, shard_edges_from_cache
from repro.launch.mesh import make_host_mesh
from repro.launch.summarize import run_distributed


def stats_equal(a: dict, b: dict, label: str) -> None:
    assert set(a) == set(b), (label, set(a) ^ set(b))
    for k in a:
        if k.endswith("wall_s"):
            continue
        assert a[k] == b[k], (label, k, a[k], b[k])


def shard_contents(arr) -> list[np.ndarray]:
    """Per-shard data ordered by global row position (not device id)."""
    shards = sorted(arr.addressable_shards,
                    key=lambda s: s.index[0].start or 0)
    return [np.asarray(s.data) for s in shards]


def main():
    assert jax.device_count() == 8
    mesh = make_host_mesh((2, 4), ("data", "model"))
    cfg = SummaryConfig(T=4, k_frac=0.35)

    # ---- 1. cache feed ≡ in-memory feed ≡ legacy construction ----------
    src, dst, v = generate("ego-facebook", seed=0, scale=0.05)
    graph, _ = make_graph(src, dst, v)
    csrc = np.asarray(graph.src, np.int32)
    cdst = np.asarray(graph.dst, np.int32)
    if csrc.size % 8 == 0:  # force the |E| % n_dev != 0 regime
        csrc, cdst = csrc[:-1], cdst[:-1]
    e = csrc.size
    assert e % 8 != 0

    workdir = tempfile.mkdtemp(prefix="ssumm-feedcheck-")
    path = write_edge_list(os.path.join(workdir, "g.txt"), csrc, cdst, v)
    g = load_graph(path)
    assert g.num_edges == e and g.num_nodes == v

    feeder = ShardFeeder()
    sh_cache = shard_edges_from_cache(g.cache_dir, mesh, feeder=feeder)
    assert sh_cache.stats.peak_staging_bytes == sh_cache.stats.shard_bytes
    assert sh_cache.stats.peak_staging_bytes < 4 * e, "staged ~full |E|"
    sh_mem = shard_edges(csrc, cdst, mesh, feeder=feeder)
    for a, b in zip(shard_contents(sh_cache.src), shard_contents(sh_mem.src)):
        assert np.array_equal(a, b)
    legacy = pad_and_shard_edges(csrc, cdst, mesh)
    assert np.array_equal(np.asarray(sh_cache.src), np.asarray(legacy[0]))
    assert np.array_equal(np.asarray(sh_cache.dst), np.asarray(legacy[1]))

    state_c, stats_c, size_g = run_distributed(None, None, v, cfg, mesh,
                                               shards=sh_cache)
    state_m, stats_m, _ = run_distributed(csrc, cdst, v, cfg, mesh)
    stats_equal(stats_c, stats_m, "cache vs in-memory metrics")
    assert np.array_equal(np.asarray(state_c.node2super),
                          np.asarray(state_m.node2super))
    assert np.array_equal(np.asarray(state_c.size), np.asarray(state_m.size))
    assert stats_c["dropped"] > 0, "sparsify tail never dropped"

    # ---- 2. shard boundaries: |E| < n_dev, empty trailing shards -------
    tsrc = np.array([0, 0, 1, 2, 3], np.int32)
    tdst = np.array([1, 2, 2, 3, 4], np.int32)
    tv = 5
    tpath = write_edge_list(os.path.join(workdir, "tiny.txt"), tsrc, tdst, tv)
    tg = load_graph(tpath)
    sh = shard_edges_from_cache(tg.cache_dir, mesh, feeder=feeder)
    assert sh.stats.shard_rows == 1 and sh.stats.padded_edges == 8
    got = shard_contents(sh.src)
    want = [np.array([x], np.int32) for x in tsrc] + [
        np.array([-1], np.int32)] * 3
    for a, b in zip(got, want):
        assert np.array_equal(a, b), (got, want)
    # all-padding shards must survive a real step: metric parity with the
    # single-device closed forms when merges are disabled
    step = make_distributed_step(mesh, cfg, tv, int(tsrc.size),
                                 capacity_factor=64.0)
    state = init_state(tv, 0)
    with mesh:
        _, st = step(sh.src, sh.dst, state, jnp.float32(1e9), jnp.uint32(1))
    pt = costs.build_pair_table(jnp.asarray(tsrc), jnp.asarray(tdst), state)
    m = costs.summary_metrics(pt, state, tv, int(tsrc.size),
                              cbar_mode=cfg.cbar_mode, re_guard=cfg.re_guard)
    np.testing.assert_allclose(float(st["size_bits"]), float(m["size_bits"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(st["re1"]), float(m["re1"]), rtol=1e-5)

    print(json.dumps({"ok": True, "E": int(e),
                      "dropped": float(stats_c["dropped"]),
                      "peak_staging_bytes":
                          int(sh_cache.stats.peak_staging_bytes),
                      "shard_bytes": int(sh_cache.stats.shard_bytes)}))


if __name__ == "__main__":
    main()
