"""Host-side properties of the partitioned tier's halo index tables.

The partitioned engine's correctness rests on two invariants of
:func:`repro.core.queries_jax.build_partition_tables` that the device
kernels cannot re-check at runtime:

  * **coverage** — on the device that owns a row, every (row, referenced
    column) pair resolves through owned ∪ halo storage: the share map
    covers all references (owned position or halo slot, never the
    sentinel), and the row map covers them through owned / resident-halo /
    dense-slab storage — the second-hop fallback is exactly the dense
    remainder, nothing leaks;
  * **determinism** — tables are a pure function of (summary, owner,
    device count, dense threshold): rebuilds (elastic re-mesh) must
    reproduce them bit-for-bit.

(The owner_hash_np ↔ MeshRules.owner bit-equivalence that makes the
host partition agree with device routing lives in
tests/test_sharding_rules.py next to the rest of the rules table.)
"""

import numpy as np
import pytest

from repro.core import queries as Q
from repro.core.queries_jax import build_partition_tables, host_padded_rows
from repro.dist.sharding import owner_hash_np
from test_queries_jax import _random_summary


def _tables_for(rng, n_dev: int, dense_row_nnz=None):
    res = _random_summary(rng, v_max=40)
    bs = Q.build_block_summary(res)
    owner = owner_hash_np(bs.ids, int(rng.integers(0, 1000)), n_dev)
    return bs, owner, build_partition_tables(bs, owner, n_dev,
                                             dense_row_nnz)


@pytest.mark.parametrize("n_dev", [1, 3, 8])
@pytest.mark.parametrize("dense_row_nnz", [None, 0, 2])
def test_halo_coverage(n_dev, dense_row_nnz):
    rng = np.random.default_rng(100 * n_dev + (dense_row_nnz or 7))
    for _ in range(10):
        bs, owner, t = _tables_for(rng, n_dev, dense_row_nnz)
        pad_cols, _, _ = host_padded_rows(bs)
        s_own, h = t.own_gids.shape[1], t.halo_gids.shape[1]
        ht = t.row_halo_gids.shape[1]
        dmax = t.dense_slots.shape[1]
        share_sent = s_own + h
        row_sent = s_own + ht + n_dev * dmax
        for q in range(n_dev):
            own = t.own_gids[q][t.own_gids[q] >= 0]
            assert np.array_equal(own, np.flatnonzero(owner == q))
            n_own = own.size
            refs_mask = pad_cols[own] >= 0
            # every real reference resolves below the sentinel; every
            # padding entry resolves TO the sentinel
            loc_share = t.loc_share[q, :n_own]
            loc_row = t.loc_row[q, :n_own]
            assert np.all(loc_share[refs_mask] < share_sent)
            assert np.all(loc_share[~refs_mask] == share_sent)
            assert np.all(loc_row[refs_mask] < row_sent)
            assert np.all(loc_row[~refs_mask] == row_sent)
            # the share-side halo is exactly the remote referenced blocks
            refs = np.unique(pad_cols[own][refs_mask])
            remote = refs[owner[refs] != q]
            assert np.array_equal(t.halo_gids[q][t.halo_gids[q] >= 0],
                                  remote)
            # halo coordinates point at the true owner slot
            hl = t.halo_gids[q][t.halo_gids[q] >= 0]
            assert np.array_equal(t.halo_src_dev[q, :hl.size], owner[hl])
            assert np.array_equal(
                t.halo_src_pos[q, :hl.size], t.block_pos[hl])
            # the row-side resident halo + dense slab partition the
            # remote references (the second-hop route is exactly the
            # dense remainder)
            dense = np.isin(remote, t.dense_gids)
            assert np.array_equal(
                t.row_halo_gids[q][t.row_halo_gids[q] >= 0],
                remote[~dense])


def test_tables_deterministic_across_rebuilds():
    rng = np.random.default_rng(5)
    res = _random_summary(rng, v_max=40)
    bs = Q.build_block_summary(res)
    owner = owner_hash_np(bs.ids, 17, 8)
    a = build_partition_tables(bs, owner, 8, dense_row_nnz=2)
    b = build_partition_tables(bs, owner, 8, dense_row_nnz=2)
    for name in ("owner", "block_pos", "own_gids", "halo_gids",
                 "halo_src_dev", "halo_src_pos", "row_halo_gids",
                 "dense_gids", "dense_slots", "loc_share", "loc_row"):
        assert np.array_equal(getattr(a, name), getattr(b, name)), name


def test_dense_threshold_moves_rows_to_second_hop():
    rng = np.random.default_rng(9)
    bs, owner, t_all = _tables_for(rng, 4, dense_row_nnz=None)
    t_cut = build_partition_tables(bs, owner, 4, dense_row_nnz=0)
    row_nnz = np.diff(bs.indptr)
    assert np.array_equal(t_cut.dense_gids, np.flatnonzero(row_nnz > 0))
    assert t_all.dense_gids.size == 0
    # with every nonempty row dense, resident row-halos are empty
    assert np.all(t_cut.row_halo_gids < 0) or np.all(
        row_nnz[t_cut.row_halo_gids[t_cut.row_halo_gids >= 0]] == 0)
