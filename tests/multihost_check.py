"""Multi-host gate: the real launcher on a 2-process × 4-device CPU mesh
must be launcher-JSON bit-identical to the single-process 8-device golden.

    PYTHONPATH=src python tests/multihost_check.py \
        --edge-list data/rmat_1m.txt.gz --T 15 --driver-chunk 1 \
        --rss-budget-mb 3072 --out multihost_report.json

Four legs, each a real ``repro.launch.summarize`` invocation (the harness
never imports jax — every subprocess owns its device topology):

  golden    — 1 process × (P·D) devices, ``--distributed``; also warms
              the CSR cache the multi-host processes feed from.
  multihost — P processes × D devices each, localhost coordinator
              (``jax.distributed``, gloo collectives — DESIGN.md §15).
              Every process's JSON must match the golden bit-for-bit on
              the metric keys, match its peers, report the
              ``cache-mmap-multihost`` feed path, and prove host-local
              staging: ``feed_local_shards == n_dev/P``,
              ``feed_bytes_copied`` exactly 1/P of the total, one staging
              shard high-water mark, and (with ``--rss-budget-mb``)
              per-process peak RSS under budget — no host ever staged a
              full-|E| array.
  resume    — same mesh with ``--checkpoint-dir``; SIGTERM lands on every
              process once a checkpoint commits, all must exit
              RESUMABLE_EXIT (75), and the relaunched ``--resume`` run
              must again match the golden bit-for-bit (PR 7's machinery,
              now with process-0 writes + cross-process preemption
              agreement).
  wire      — ``tests/wire_check.py`` on the same 2-process mesh: the
              compressed-payload byte counters and error-feedback
              locality, across a real process boundary.

``--bench-out`` writes per-leg wall clocks in the
``scripts/check_bench.py --bench multihost`` artifact format.
"""

import argparse
import json
import os
import shutil
import signal
import socket
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESUMABLE_EXIT = 75  # repro.runtime.RESUMABLE_EXIT (harness is jax-free)

#: launcher JSON keys that must be bit-identical across topologies
EXACT_KEYS = ("V", "E", "mode", "size_bits", "size_bits_before_sparsify",
              "relative_size", "re1", "re2", "num_supernodes",
              "num_superedges", "superedges_dropped")


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launcher_cmd(args, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.summarize",
           "--edge-list", args.edge_list,
           "--k-frac", str(args.k_frac), "--T", str(args.T),
           "--seed", str(args.seed), "--group-size", str(args.group_size),
           "--driver-chunk", str(args.driver_chunk), "--distributed"]
    if args.chunk_edges:
        cmd += ["--chunk-edges", str(args.chunk_edges)]
    if args.rss_budget_mb is not None:
        cmd += ["--rss-budget-mb", str(args.rss_budget_mb)]
    return cmd + list(extra)


def env_for(devices):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return env


def last_json(stdout):
    for line in reversed(stdout.strip().splitlines()):
        line = line.strip()
        if line.endswith("}"):
            text = stdout[: stdout.rindex(line) + len(line)]
            start = text.rindex("\n{") if "\n{" in text else text.index("{")
            return json.loads(text[start:])
    raise ValueError(f"no JSON object in stdout:\n{stdout}")


def committed_steps(ckdir):
    if not os.path.isdir(ckdir):
        return []
    return sorted(int(n[len("step_"):]) for n in os.listdir(ckdir)
                  if n.startswith("step_")
                  and os.path.exists(os.path.join(ckdir, n, "COMMIT")))


def compare(got, want, exact):
    bad = []
    for k in exact:
        if k not in want and k not in got:
            continue
        if got.get(k) != want.get(k):
            bad.append(f"{k}: got {got.get(k)!r} want {want.get(k)!r}")
    return bad


class Fleet:
    """P launcher processes sharing one localhost coordinator."""

    def __init__(self, args, extra, workdir, tag):
        port = free_port()
        self.procs, self.outs, self.errs = [], [], []
        for i in range(args.num_processes):
            cmd = launcher_cmd(args, extra=tuple(extra) + (
                "--coordinator", f"localhost:{port}",
                "--num-processes", str(args.num_processes),
                "--process-id", str(i)))
            out = open(os.path.join(workdir, f"{tag}_p{i}.out"), "w+")
            err = open(os.path.join(workdir, f"{tag}_p{i}.err"), "w+")
            self.procs.append(subprocess.Popen(
                cmd, env=env_for(args.devices_per_process),
                stdout=out, stderr=err))
            self.outs.append(out)
            self.errs.append(err)

    def poll_done(self):
        return all(p.poll() is not None for p in self.procs)

    def signal_all(self, sig):
        for p in self.procs:
            if p.poll() is None:
                os.kill(p.pid, sig)

    def wait(self, timeout):
        deadline = time.time() + timeout
        for p in self.procs:
            p.wait(timeout=max(deadline - time.time(), 1.0))

    def finish(self, timeout):
        """Wait, then return (rcs, stdouts, stderrs) and close the files."""
        try:
            self.wait(timeout)
        finally:
            for p in self.procs:
                if p.poll() is None:
                    p.kill()
                    p.wait()
        outs, errs = [], []
        for f in self.outs + self.errs:
            f.flush()
            f.seek(0)
        for f in self.outs:
            outs.append(f.read())
            f.close()
        for f in self.errs:
            errs.append(f.read())
            f.close()
        return [p.returncode for p in self.procs], outs, errs


def main():
    ap = argparse.ArgumentParser(
        description="process-spanning-mesh bit-identity gate")
    ap.add_argument("--edge-list", required=True)
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--chunk-edges", type=int, default=None)
    ap.add_argument("--driver-chunk", type=int, default=1)
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=4)
    ap.add_argument("--kill-step", type=int, default=2,
                    help="resume leg: SIGTERM the fleet once this "
                         "checkpoint step has committed")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--checkpoint-keep", type=int, default=3)
    ap.add_argument("--rss-budget-mb", type=float, default=None,
                    help="per-process peak-RSS gate for every leg (the "
                         "no-full-|E|-staging proof)")
    ap.add_argument("--skip-wire", action="store_true",
                    help="skip the wire_check leg")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--out", default=None,
                    help="write the report JSON here (CI artifact)")
    ap.add_argument("--bench-out", default=None,
                    help="write check_bench 'multihost' rows here")
    args = ap.parse_args()
    n_total = args.num_processes * args.devices_per_process

    workdir = args.workdir or os.path.join(
        os.environ.get("TMPDIR", "/tmp"), f"multihost_{os.getpid()}")
    os.makedirs(workdir, exist_ok=True)
    report = {"ok": True, "legs": {}, "errors": []}
    walls = {}

    def fail(msg):
        report["ok"] = False
        report["errors"].append(msg)

    # ---- golden: 1 process x n_total devices (also warms the cache) ------
    t0 = time.time()
    out = subprocess.run(launcher_cmd(args), env=env_for(n_total),
                         capture_output=True, text=True,
                         timeout=args.timeout)
    walls["golden"] = time.time() - t0
    if out.returncode != 0:
        print(out.stderr[-4000:], file=sys.stderr)
        raise SystemExit(f"golden run failed rc={out.returncode}")
    golden = last_json(out.stdout)
    report["legs"]["golden"] = {k: golden.get(k) for k in EXACT_KEYS}
    report["legs"]["golden"]["peak_rss_mb"] = golden.get("peak_rss_mb")

    # ---- multihost: P processes x D devices ------------------------------
    t0 = time.time()
    fleet = Fleet(args, (), workdir, "mh")
    rcs, outs, errs = fleet.finish(args.timeout)
    walls["multihost"] = time.time() - t0
    leg = {"rcs": rcs, "procs": []}
    jsons = []
    for i, (rc, so, se) in enumerate(zip(rcs, outs, errs)):
        if rc != 0:
            fail(f"multihost p{i} rc={rc}: {se[-2000:]}")
            continue
        j = last_json(so)
        jsons.append(j)
        for msg in compare(j, golden, EXACT_KEYS):
            fail(f"multihost p{i} vs golden: {msg}")
        if j.get("feed_path") != "cache-mmap-multihost":
            fail(f"multihost p{i} feed_path={j.get('feed_path')!r}")
        if j.get("process_count") != args.num_processes:
            fail(f"multihost p{i} process_count={j.get('process_count')}")
        # host-local staging proof: this process staged exactly its own
        # 1/P of the shards, one staging buffer high-water mark
        want_shards = n_total // args.num_processes
        if j.get("feed_local_shards") != want_shards:
            fail(f"multihost p{i} feed_local_shards="
                 f"{j.get('feed_local_shards')} != {want_shards}")
        want_copied = want_shards * j["feed_shard_bytes"] * 2  # both columns
        if j.get("feed_bytes_copied") != want_copied:
            fail(f"multihost p{i} feed_bytes_copied="
                 f"{j.get('feed_bytes_copied')} != {want_copied}")
        if j.get("feed_peak_staging_bytes") != j.get("feed_shard_bytes"):
            fail(f"multihost p{i} staged more than one shard: "
                 f"{j.get('feed_peak_staging_bytes')}")
        leg["procs"].append({
            "process_index": j.get("process_index"),
            "peak_rss_mb": j.get("peak_rss_mb"),
            "feed_local_shards": j.get("feed_local_shards"),
            "feed_bytes_copied": j.get("feed_bytes_copied"),
        })
    for j in jsons[1:]:
        for msg in compare(j, jsons[0], EXACT_KEYS):
            fail(f"multihost peers disagree: {msg}")
    report["legs"]["multihost"] = leg

    # ---- resume: SIGTERM the whole fleet, then --resume ------------------
    ckdir = os.path.join(workdir, "ck")
    shutil.rmtree(ckdir, ignore_errors=True)
    ck_extra = ("--checkpoint-dir", ckdir,
                "--checkpoint-every", str(args.checkpoint_every),
                "--checkpoint-keep", str(args.checkpoint_keep))
    t0 = time.time()
    fleet = Fleet(args, ck_extra, workdir, "kill")
    delivered = False
    deadline = time.time() + args.timeout
    while time.time() < deadline and not fleet.poll_done():
        steps = committed_steps(ckdir)
        if steps and steps[-1] >= args.kill_step:
            fleet.signal_all(signal.SIGTERM)
            delivered = True
            break
        time.sleep(0.01)
    rcs, outs, errs = fleet.finish(args.timeout)
    leg = {"delivered": delivered, "kill_rcs": rcs}
    errors_before_kill = len(report["errors"])
    if not delivered:
        # the fleet finished before the kill step committed — compare the
        # completed run directly (kill step too late for this workload)
        leg["outcome"] = "completed"
        for i, (rc, so) in enumerate(zip(rcs, outs)):
            if rc != 0:
                fail(f"resume-leg p{i} completed rc={rc}")
            else:
                for msg in compare(last_json(so), golden, EXACT_KEYS):
                    fail(f"resume-leg completed p{i}: {msg}")
    else:
        for i, (rc, so, se) in enumerate(zip(rcs, outs, errs)):
            if rc != RESUMABLE_EXIT:
                fail(f"resume-leg p{i} SIGTERM rc={rc} != {RESUMABLE_EXIT}"
                     f"\n{se[-2000:]}")
            elif not last_json(so).get("preempted"):
                fail(f"resume-leg p{i} printed no preempted record")
        if not committed_steps(ckdir):
            fail("resume-leg: no committed checkpoint to resume from")
        elif len(report["errors"]) == errors_before_kill:
            leg["resume_from"] = committed_steps(ckdir)[-1]
            fleet = Fleet(args, ck_extra + ("--resume",), workdir, "resume")
            rcs, outs, errs = fleet.finish(args.timeout)
            leg["resume_rcs"] = rcs
            for i, (rc, so, se) in enumerate(zip(rcs, outs, errs)):
                if rc != 0:
                    fail(f"resume p{i} rc={rc}: {se[-2000:]}")
                    continue
                j = last_json(so)
                if j.get("resumed_from") is None:
                    fail(f"resume p{i} did not report resumed_from")
                for msg in compare(j, golden, EXACT_KEYS):
                    fail(f"resume p{i} vs golden: {msg}")
            leg["outcome"] = "resumed"
    walls["resume"] = time.time() - t0
    report["legs"]["resume"] = leg

    # ---- wire: compressed all-reduce accounting across the boundary ------
    if not args.skip_wire:
        t0 = time.time()
        port = free_port()
        procs, files = [], []
        for i in range(args.num_processes):
            env = env_for(args.devices_per_process)
            env.update(SSUMM_COORDINATOR=f"localhost:{port}",
                       SSUMM_NUM_PROCESSES=str(args.num_processes),
                       SSUMM_PROCESS_ID=str(i))
            out = open(os.path.join(workdir, f"wire_p{i}.out"), "w+")
            procs.append(subprocess.Popen(
                [sys.executable, os.path.join(ROOT, "tests",
                                              "wire_check.py")],
                env=env, stdout=out, stderr=subprocess.DEVNULL))
            files.append(out)
        leg = {"rcs": []}
        for i, (p, f) in enumerate(zip(procs, files)):
            try:
                rc = p.wait(timeout=args.timeout)
            except subprocess.TimeoutExpired:
                p.kill()
                rc = p.wait()
            leg["rcs"].append(rc)
            f.flush()
            f.seek(0)
            body = f.read()
            f.close()
            if rc != 0:
                fail(f"wire p{i} rc={rc}: {body[-1500:]}")
        walls["wire"] = time.time() - t0
        report["legs"]["wire"] = leg

    report["walls"] = walls
    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    if args.bench_out:
        rows = [{"bench": "multihost", "leg": leg_name,
                 # golden is the 1-process reference on the same global mesh
                 "processes": (1 if leg_name == "golden"
                               else args.num_processes),
                 "devices_per_process": (n_total if leg_name == "golden"
                                         else args.devices_per_process),
                 "wall_s": wall}
                for leg_name, wall in walls.items()]
        os.makedirs(os.path.dirname(os.path.abspath(args.bench_out)),
                    exist_ok=True)
        with open(args.bench_out, "w") as f:
            json.dump(rows, f, indent=1)
    raise SystemExit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
