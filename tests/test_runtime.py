"""Runtime substrate: checkpoint atomicity/restore, elastic mesh planning,
straggler detection, data determinism, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import Loader, SyntheticTokens, TokenDatasetConfig
from repro.dist import CompressConfig, decode_int8, encode_int8, encode_topk
from repro.dist.compress import init_error_buffers, payload_bytes
from repro.runtime import CheckpointManager, StragglerMonitor, plan_mesh


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.standard_normal(3), jnp.float32),
                   "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(10, tree, extra={"loss": 1.5})
    got, step, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 10 and extra["loss"] == 1.5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), got, tree)


def test_checkpoint_keep_n_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree())
    # simulate a crash mid-write: directory without COMMIT
    os.makedirs(tmp_path / "step_0000000009")
    assert mgr.latest_step() == 5


def test_checkpoint_restore_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((3, 3))})


def test_checkpoint_tmp_dir_ignored_and_gced(tmp_path):
    """A crash mid-write leaves only a ``.tmp-`` dir: restore never sees
    it, and the next successful save garbage-collects it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(4, _tree())
    # simulate a writer killed between makedirs and the COMMIT fsync
    junk = tmp_path / ".tmp-9"
    junk.mkdir()
    (junk / "w.npy").write_bytes(b"partial garbage")
    assert mgr.latest_step() == 4
    got, step, _ = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 4
    mgr.save(5, _tree(1))
    assert not junk.exists()
    assert mgr.all_steps() == [4, 5]


def test_checkpoint_save_stats_and_step_bytes(tmp_path):
    """Per-step accounting feeds the EXPERIMENTS §Resume overhead table:
    snapshot time (what the driver pays), write time, committed bytes."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save_async(2, tree, extra={"t_next": 3})
    mgr.wait()
    st = mgr.save_stats[2]
    assert st["snapshot_wall_s"] > 0.0
    assert st["write_wall_s"] > 0.0
    assert st["bytes"] == mgr.step_bytes(2) > 0
    # payload bytes dominate: every leaf is on disk
    leaf_bytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(tree))
    assert st["bytes"] > leaf_bytes
    assert mgr.step_bytes(99) == 0  # absent step


# ---------------------------------------------------------------------------
# elastic planning
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 4096), batch=st.sampled_from([32, 256, 1024]))
@settings(max_examples=50, deadline=None)
def test_plan_mesh_properties(n, batch):
    plan = plan_mesh(n, global_batch=batch, want_model=16, want_pods=2)
    assert plan.n_devices == n
    assert "model" in plan.axes and "data" in plan.axes
    # model axis never exceeds the requested TP degree
    model = plan.shape[plan.axes.index("model")]
    assert model <= 16
    # global batch is preserved: dp · per_device · accum ≥ batch
    dp = plan.n_devices // model
    assert dp * plan.per_device_batch * plan.accum_steps >= min(batch, dp)


def test_plan_mesh_survivor_shrink():
    full = plan_mesh(512, global_batch=256, want_model=16, want_pods=2)
    assert full.shape == (2, 16, 16)
    survivor = plan_mesh(448, global_batch=256, want_model=16, want_pods=2)
    assert survivor.n_devices == 448  # keeps every surviving chip busy
    model = survivor.shape[survivor.axes.index("model")]
    assert 448 % model == 0


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------


def test_straggler_flags_spike():
    mon = StragglerMonitor(warmup_steps=3, z_threshold=3.0, ratio_threshold=1.5)
    flags = [mon.observe(i, 0.1 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flags)
    assert mon.observe(20, 1.0)  # 10× spike
    assert len(mon.events) == 1 and mon.events[0].ratio > 5
    # EMA not polluted by the spike
    assert mon.mean < 0.2


def test_straggler_callback():
    mon = StragglerMonitor(warmup_steps=2, z_threshold=2.0, ratio_threshold=1.5)
    seen = []
    mon.on_straggler(seen.append)
    for i in range(10):
        mon.observe(i, 0.05)
    mon.observe(10, 0.5)
    assert len(seen) == 1 and seen[0].step == 10


# ---------------------------------------------------------------------------
# data determinism
# ---------------------------------------------------------------------------


def test_tokens_deterministic_and_sharded():
    cfg = TokenDatasetConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticTokens(cfg)
    a, b = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(ds.batch(5), ds.batch(6))
    assert a.min() >= 0 and a.max() < 128
    # rank shards tile the global batch exactly
    parts = [ds.batch_for_rank(5, r, 4) for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), a)


def test_loader_resume_stream():
    cfg = TokenDatasetConfig(vocab=64, seq_len=16, global_batch=2, seed=0)
    ds = SyntheticTokens(cfg)
    loader = Loader(ds.batch, start_index=3, prefetch=2)
    idx, batch = next(loader)
    assert idx == 3
    np.testing.assert_array_equal(np.asarray(batch), ds.batch(3))
    loader.close()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    q, s = encode_int8(g)
    back = decode_int8(q, s)
    err = float(jnp.max(jnp.abs(back["a"] - g["a"])))
    assert err <= float(s["a"]) * 0.5 + 1e-6  # half-ULP of the scale


def test_topk_error_feedback_conserves_signal():
    """Over many steps, sent + residual ≡ the accumulated gradient signal."""
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.standard_normal(256), jnp.float32)}
    err = init_error_buffers(g)
    sent_total = jnp.zeros(256)
    for _ in range(5):
        sent, err = encode_topk(g, err, ratio=0.1)
        sent_total = sent_total + sent["a"]
        nz = int(jnp.sum(sent["a"] != 0.0))
        assert nz <= 26  # ~top 10%
    recon = sent_total + err["a"]
    np.testing.assert_allclose(np.asarray(recon), 5 * np.asarray(g["a"]),
                               rtol=1e-5, atol=1e-5)


def test_payload_accounting():
    g = {"a": jnp.zeros((1000,), jnp.float32)}
    full = payload_bytes(g, CompressConfig("none"))
    int8 = payload_bytes(g, CompressConfig("int8"))
    topk = payload_bytes(g, CompressConfig("topk", topk_ratio=0.05))
    assert full == 4000.0
    assert int8 < full / 3.5
    assert topk < full / 9.0
