"""Subprocess body: a2a MoE vs GSPMD MoE numerical parity on an 8-device
host mesh (run via tests/test_moe.py)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.dist.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models import moe as moe_lib
from repro.models.common import split_tree


def main():
    assert jax.device_count() == 8
    cfg = get_smoke_config("granite_moe_3b_a800m")
    # high capacity so neither path drops tokens → outputs must match;
    # pad experts to the 4-way EP axis of the test mesh
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     padded_experts=8))
    mesh = make_host_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, "train")

    px = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, ep=4)
    params, _ = split_tree(px)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.float32)

    with mesh:
        y_ref, aux_ref = jax.jit(
            lambda p, xx: moe_lib.apply_moe_gspmd(p, xx, cfg, rules)
        )(params, x)
        y_a2a, aux_a2a = jax.jit(
            lambda p, xx: moe_lib.apply_moe_a2a(p, xx, cfg, rules)
        )(params, x)

    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_a2a["moe_aux"]),
                               float(aux_ref["moe_aux"]), rtol=1e-4)
    assert float(aux_a2a["moe_drop_frac"]) == 0.0
    assert float(aux_ref["moe_drop_frac"]) == 0.0

    # gradients flow through the a2a path
    def loss(p):
        y, _ = moe_lib.apply_moe_a2a(p, x, cfg, rules)
        return jnp.sum(y * y)

    with mesh:
        g = jax.jit(jax.grad(loss))(params)
    gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0.0

    print(json.dumps({"ok": True, "gnorm": gnorm}))


if __name__ == "__main__":
    main()
