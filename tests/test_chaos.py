"""Chaos-injection gate (subprocess): SIGTERM/SIGKILL the real launcher at
seeded checkpoint steps, resume, and require launcher-JSON bit-identity
with the uninterrupted golden — including an 8→4 device elastic shrink on
the resume. The CI ``chaos`` job runs the same harness against the
1.1M-edge ingest fixture."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _chaos(tmp_path, *extra, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "chaos_check.py"),
         "--workdir", str(tmp_path), *extra],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout[-6000:]}\nstderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout[out.stdout.index("{"):])


@pytest.mark.slow
def test_chaos_local_kill_and_resume(tmp_path):
    rep = _chaos(tmp_path, "--dataset", "dblp", "--scale", "0.05",
                 "--T", "12", "--driver-chunk", "1",
                 "--kill", "TERM:2", "--kill", "KILL:4")
    assert rep["ok"]
    assert rep["checkpoint_bytes"] > 0
    for scen in rep["scenarios"]:
        assert scen["delivered"] and not scen["errors"]
    # at least the SIGKILL scenario must have exercised a real resume
    # (TERM may legitimately finish if the signal lands past the last
    # sync point — the harness then checks the completed JSON instead)
    kill = next(s for s in rep["scenarios"] if s["signal"] == "KILL")
    assert kill["outcome"] == "resumed" and kill["kill_rc"] == -9


@pytest.mark.slow
def test_chaos_distributed_shrink_8_to_4(tmp_path):
    rep = _chaos(tmp_path, "--dataset", "dblp", "--scale", "0.02",
                 "--T", "10", "--driver-chunk", "1", "--distributed",
                 "--devices", "8", "--resume-devices", "4",
                 "--kill", "KILL:2")
    assert rep["ok"]
    scen = rep["scenarios"][0]
    assert scen["outcome"] == "resumed" and scen["kill_rc"] == -9
    assert scen["resumed_from_json"] is not None
    assert "distributed" in rep["golden"]["mode"]
