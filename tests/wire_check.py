"""Subprocess body: wire accounting of the shard_map'd compressed
all-reduce (``repro.dist.compress.compressed_allreduce``).

Runs on however many devices XLA_FLAGS exposes — and, when the
``SSUMM_COORDINATOR``/``SSUMM_NUM_PROCESSES``/``SSUMM_PROCESS_ID`` env
vars are set, on a real process-spanning mesh (DESIGN.md §15), where the
int8/top-k payloads cross the process boundary. For every wire format it
asserts:

  * the psum'd byte counter equals ``n_dev × payload_bytes(tree, cfg)``
    — the exact accounting ``launch/train.py`` prints and asserts;
  * the summed tree matches a host-side reference built from the same
    per-device contributions (exact for ``none``; rtol 1e-5 for the
    codecs' f32 reduction order);
  * top-k conservation: each device's ``sent + residual`` equals its
    accumulated signal exactly — nothing dropped, only delayed;
  * the error-feedback residual is **device-local state**: every
    addressable shard of the returned residual equals the host reference
    for that device index (distinct per device, never mixed by the
    collective), and each process can only ever see its own shards.

Prints one JSON line per process; ``tests/test_distributed.py`` runs the
single-process variant, ``tests/multihost_check.py`` the 2-process one.
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json

from repro.launch.mesh import bootstrap_distributed

dist = bootstrap_distributed()  # env-driven; no-op single-process

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import make_mesh, shard_map
from repro.dist.compress import (
    CompressConfig,
    compressed_allreduce,
    decode_int8,
    encode_int8,
    payload_bytes,
)

# leaf shapes chosen to exercise ceil(ratio·n), scalar broadcasting and
# multi-dim reshapes
SHAPES = {"w": (33, 7), "b": (13,), "s": ()}


def host_topk_ref(g, err, ratio):
    """The exact per-leaf math of the collective's top-k path, on host."""
    acc = g.astype(np.float32) + err
    flat = acc.ravel().copy()
    k = max(int(np.ceil(ratio * max(flat.size, 1))), 1)
    # match jax.lax.top_k tie-breaking: stable order on descending |x|
    idx = np.argsort(-np.abs(flat), kind="stable")[:k]
    vals = flat[idx].astype(g.dtype)
    sent = np.zeros_like(flat)
    sent[idx] = vals
    res = flat.copy()
    res[idx] -= vals.astype(np.float32)
    return sent.reshape(g.shape), res.reshape(g.shape), vals, idx


def main():
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(7)
    # every process derives the same full stack deterministically; each
    # device's contribution is its slice on dim 0 (all distinct)
    stacked = {k: rng.normal(size=(n_dev,) + shp).astype(np.float32)
               for k, shp in SHAPES.items()}
    spec = {k: NamedSharding(mesh, P(("data",)))
            for k in stacked}
    sharded = {
        k: jax.make_array_from_callback(
            v.shape, spec[k], lambda i, v=v: v[i])
        for k, v in stacked.items()
    }
    template = {k: np.zeros(shp, np.float32) for k, shp in SHAPES.items()}
    report = {"ok": True, "process_count": dist.process_count,
              "process_index": dist.process_index, "n_dev": n_dev,
              "errors": [], "wire_bytes": {}}

    def check(name, cond, detail=""):
        if not cond:
            report["ok"] = False
            report["errors"].append(f"{name}: {detail}")

    for kind in ("none", "int8", "topk"):
        cfg = CompressConfig(kind, topk_ratio=0.1)

        def body(x, e):
            g = jax.tree.map(lambda a: jnp.squeeze(a, 0), x)
            err = jax.tree.map(lambda a: jnp.squeeze(a, 0), e)
            s, ne, wb = compressed_allreduce(g, err, cfg, ("data",))
            if ne is None:
                ne = err
            return s, jax.tree.map(lambda a: a[None], ne), wb

        err0 = {k: np.zeros((n_dev,) + shp, np.float32)
                for k, shp in SHAPES.items()}
        err_sharded = {
            k: jax.make_array_from_callback(
                v.shape, spec[k], lambda i, v=v: v[i])
            for k, v in err0.items()
        }
        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(("data",)), P(("data",))),
            out_specs=(P(), P(("data",)), P()),
            check_vma=False,
        ))
        summed, new_err, wire = fn(sharded, err_sharded)
        wire = float(wire)
        expected = n_dev * payload_bytes(template, cfg)
        report["wire_bytes"][kind] = {"measured": wire, "priced": expected}
        check(f"{kind}/bytes", np.isclose(wire, expected, rtol=1e-6),
              f"measured {wire} != priced {expected}")

        if kind == "none":
            # the cross-process psum's partial-sum grouping (local reduce,
            # then gloo ring) differs from np.sum's left-to-right order —
            # ~1 ulp of the addends, so compare with an absolute floor
            ref = {k: v.sum(axis=0, dtype=np.float32)
                   for k, v in stacked.items()}
            for k in SHAPES:
                check(f"none/sum/{k}",
                      np.allclose(np.asarray(summed[k]), ref[k], rtol=1e-5,
                                  atol=1e-5),
                      "psum mismatch")
        elif kind == "int8":
            ref = {}
            for k, v in stacked.items():
                acc = np.zeros(SHAPES[k], np.float32)
                for i in range(n_dev):
                    q, s = encode_int8(v[i])
                    acc = acc + np.asarray(decode_int8(q, s))
                ref[k] = acc
            for k in SHAPES:
                check(f"int8/sum/{k}",
                      np.allclose(np.asarray(summed[k]), ref[k], rtol=1e-5,
                                  atol=1e-6),
                      "decoded sum mismatch")
        else:  # topk
            sent_sum = {k: np.zeros(SHAPES[k], np.float32) for k in SHAPES}
            res_ref = {k: np.zeros_like(err0[k]) for k in SHAPES}
            for k, v in stacked.items():
                for i in range(n_dev):
                    sent, res, _vals, _idx = host_topk_ref(
                        v[i], err0[k][i], cfg.topk_ratio)
                    sent_sum[k] += sent
                    res_ref[k][i] = res
                    # conservation: sent + residual == accumulated signal
                    check(f"topk/conserve/{k}/{i}",
                          np.array_equal(sent + res,
                                         v[i].astype(np.float32)),
                          "sent+residual != acc")
            for k in SHAPES:
                check(f"topk/sum/{k}",
                      np.allclose(np.asarray(summed[k]), sent_sum[k],
                                  rtol=1e-5, atol=1e-6),
                      f"{np.asarray(summed[k])} vs {sent_sum[k]}")
                # error feedback is per-device state: this process can
                # address only its own shards, and each must equal the
                # host reference for exactly that device's contribution
                shards = new_err[k].addressable_shards
                check(f"topk/err_local_count/{k}",
                      len(shards) == jax.local_device_count(),
                      f"{len(shards)} addressable err shards")
                for sh in shards:
                    i = sh.index[0].start or 0
                    got = np.asarray(sh.data)[0]
                    check(f"topk/err_local/{k}/{i}",
                          np.allclose(got, res_ref[k][i], rtol=1e-6,
                                      atol=1e-7),
                          "residual shard != per-device reference")

    print(json.dumps(report))
    raise SystemExit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
