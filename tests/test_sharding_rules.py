"""MeshRules / make_rules: mode tables, spec assembly, override validation.

Runs on the single CPU device (a 1×1 mesh exercises the full code path —
axis *names* are what the validation is about, not axis sizes)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import MODES, make_rules


def _mesh(axes=("data", "model")):
    devs = np.asarray(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(devs, axes)


@pytest.mark.parametrize("mode", MODES)
def test_mode_tables_build_on_any_mesh(mode):
    rules = make_rules(_mesh(), mode)
    assert rules.n_devices == 1
    assert rules.axis_names == ("data", "model")
    # every logical name resolves without KeyError
    for name in rules.table:
        rules.mesh_axes(name)
    with pytest.raises(KeyError):
        rules.mesh_axes("not_a_logical_axis")


def test_summarize_mode_shards_edges_over_all_axes():
    rules = make_rules(_mesh(), "summarize")
    assert rules.edge_spec == P(("data", "model"))
    assert rules.replicated == P()


def test_override_unknown_logical_name_raises():
    with pytest.raises(KeyError, match="unknown logical axis 'sequ'"):
        make_rules(_mesh(), "serve", overrides={"sequ": "model"})


def test_override_unknown_mesh_axis_raises():
    """The ROADMAP gap: 'seq=modell' used to silently replicate."""
    with pytest.raises(ValueError, match="not an axis of this mesh"):
        make_rules(_mesh(), "serve", overrides={"seq": "modell"})
    with pytest.raises(ValueError, match="mesh axes: \\('data', 'model'\\)"):
        make_rules(_mesh(), "train", overrides={"batch": ("data", "pod")})


def test_override_duplicate_mesh_axis_raises():
    with pytest.raises(ValueError, match="more than once"):
        make_rules(_mesh(), "train", overrides={"batch": ("data", "data")})


def test_override_non_string_entry_raises():
    with pytest.raises(ValueError):
        make_rules(_mesh(), "train", overrides={"batch": (1,)})


def test_valid_overrides_accepted():
    rules = make_rules(_mesh(), "serve",
                       overrides={"seq": None, "batch": ("data", "model")})
    assert rules.table["seq"] is None
    assert rules.table["batch"] == ("data", "model")
    # owner hash stays well-defined after overrides
    import jax.numpy as jnp

    own = rules.owner(jnp.arange(16, dtype=jnp.int32), jnp.uint32(3))
    assert int(own.max()) < rules.n_devices
