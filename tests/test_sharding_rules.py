"""MeshRules / make_rules: mode tables, spec assembly, override validation.

Runs on the single CPU device (a 1×1 mesh exercises the full code path —
axis *names* are what the validation is about, not axis sizes)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import MODES, MeshRules, make_rules, owner_hash_np


def _mesh(axes=("data", "model")):
    devs = np.asarray(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(devs, axes)


@pytest.mark.parametrize("mode", MODES)
def test_mode_tables_build_on_any_mesh(mode):
    rules = make_rules(_mesh(), mode)
    assert rules.n_devices == 1
    assert rules.axis_names == ("data", "model")
    # every logical name resolves without KeyError
    for name in rules.table:
        rules.mesh_axes(name)
    with pytest.raises(KeyError):
        rules.mesh_axes("not_a_logical_axis")


def test_summarize_mode_shards_edges_over_all_axes():
    rules = make_rules(_mesh(), "summarize")
    assert rules.edge_spec == P(("data", "model"))
    assert rules.replicated == P()


def test_eval_mode_table_is_pure_data_parallel():
    """Offline eval: the batch spreads over every mesh axis, weights and
    activations replicate — no tensor-parallel assignment survives."""
    rules = make_rules(_mesh(), "eval")
    assert rules.mesh_axes("batch") == ("data", "model")
    for name, assign in rules.table.items():
        if name != "batch":
            assert assign is None, name
    assert rules.spec(("batch",)) == P(("data", "model"))


def test_owner_hash_np_matches_device_hash():
    """The host-side partition (owner_hash_np) and the device-side router
    (MeshRules.owner) must agree bit-for-bit, or the partitioned query
    tier would route probes to devices that do not hold the row."""
    import types

    import jax.numpy as jnp

    ids = np.arange(1024, dtype=np.int32)
    for n_dev in (1, 4, 8):
        mesh = types.SimpleNamespace(size=n_dev, axis_names=("data",))
        rules = MeshRules(mesh=mesh, mode="summarize", table={})
        for salt in (0, 1, 17, 2**31 - 1):
            want = np.asarray(rules.owner(jnp.asarray(ids),
                                          jnp.uint32(salt)))
            got = owner_hash_np(ids, salt, n_dev)
            assert np.array_equal(got, want), (n_dev, salt)
            assert got.min() >= 0 and got.max() < n_dev


def test_override_unknown_logical_name_raises():
    with pytest.raises(KeyError, match="unknown logical axis 'sequ'"):
        make_rules(_mesh(), "serve", overrides={"sequ": "model"})


def test_override_unknown_mesh_axis_raises():
    """The ROADMAP gap: 'seq=modell' used to silently replicate."""
    with pytest.raises(ValueError, match="not an axis of this mesh"):
        make_rules(_mesh(), "serve", overrides={"seq": "modell"})
    with pytest.raises(ValueError, match="mesh axes: \\('data', 'model'\\)"):
        make_rules(_mesh(), "train", overrides={"batch": ("data", "pod")})


def test_override_duplicate_mesh_axis_raises():
    with pytest.raises(ValueError, match="more than once"):
        make_rules(_mesh(), "train", overrides={"batch": ("data", "data")})


def test_override_non_string_entry_raises():
    with pytest.raises(ValueError):
        make_rules(_mesh(), "train", overrides={"batch": (1,)})


def test_valid_overrides_accepted():
    rules = make_rules(_mesh(), "serve",
                       overrides={"seq": None, "batch": ("data", "model")})
    assert rules.table["seq"] is None
    assert rules.table["batch"] == ("data", "model")
    # owner hash stays well-defined after overrides
    import jax.numpy as jnp

    own = rules.owner(jnp.arange(16, dtype=jnp.int32), jnp.uint32(3))
    assert int(own.max()) < rules.n_devices
