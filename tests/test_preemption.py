"""Preemption safety end-to-end (subprocess): SIGTERM/SIGINT trigger a
cooperative save-and-exit with ``RESUMABLE_EXIT``, the committed
checkpoint resumes bit-identically, a half-written ``.tmp-`` directory is
ignored, and a second signal hard-exits immediately."""

import json
import os
import signal
import subprocess
import sys

import pytest

from repro.runtime import RESUMABLE_EXIT

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(mode, d, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "preempt_check.py"),
         mode, "--dir", str(d)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def _last_json(out):
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("mode,signame", [("term", "SIGTERM"),
                                          ("int", "SIGINT")])
def test_signal_saves_and_resumes_bit_identical(tmp_path, mode, signame):
    d = tmp_path / mode
    out = _run(mode, d)
    assert out.returncode == RESUMABLE_EXIT, \
        f"{signame}: rc={out.returncode}\nstderr:\n{out.stderr}"
    rec = _last_json(out)
    assert rec["preempted"] and rec["step"] == 2  # first chunk boundary
    # the save is committed, not torn
    step_dir = d / f"step_{rec['step']:010d}"
    assert (step_dir / "COMMIT").exists()
    assert (step_dir / "manifest.json").exists()

    # a torn half-write next to it must not confuse restore…
    junk = d / ".tmp-99"
    junk.mkdir()
    (junk / "garbage.npy").write_bytes(b"\x00" * 16)

    golden = _last_json(_run("golden", tmp_path / "unused"))
    resumed = _run("resume", d)
    assert resumed.returncode == 0, resumed.stderr
    rec_r = _last_json(resumed)
    assert rec_r.pop("resumed_from") == rec["step"]
    assert rec_r == golden  # bit-identical final metrics + partition sums
    # …and the next save's GC has cleared it
    assert not junk.exists()


def test_second_signal_hard_exits(tmp_path):
    out = _run("double", tmp_path / "double")
    assert out.returncode == 128 + signal.SIGTERM, \
        f"rc={out.returncode}\nstderr:\n{out.stderr}"
