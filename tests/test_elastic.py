"""Elastic restart: checkpoint on one mesh shape, reshard-on-restore onto a
different survivor mesh, training continues bit-compatibly (subprocess —
needs its own 8-device jax init)."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_elastic_reshard_on_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "elastic_check.py")],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    # resumed losses equal the no-restart reference (same stream, same state)
    assert rec["losses_resumed"] == pytest.approx(rec["losses_reference"],
                                                  rel=2e-4)
