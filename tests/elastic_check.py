"""Subprocess body: elastic restart end-to-end on 8 host devices.

Simulates losing half a pod: train on a (2, 4) mesh with FSDP+TP shardings,
checkpoint, rebuild a (4,) × (2,)-shaped *different* mesh as the survivor
plan would, restore with the new mesh's shardings (reshard-on-restore), and
continue training — losses must continue from the same state (first restored
step's loss equals a no-restart run's loss at that step).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import tempfile

import jax
import numpy as np

from repro.configs import RunConfig, get_smoke_config
from repro.data import SyntheticTokens, TokenDatasetConfig
from repro.dist.sharding import make_rules
from repro.launch.lowering import _tree_shardings
from repro.launch.train import build_train_step
from repro.models.api import build_model
from repro.optim import adamw_init
from repro.runtime import CheckpointManager, make_mesh_from_plan, plan_mesh


def setup(mesh, cfg, run, seed=0):
    rules = make_rules(mesh, "train")
    model = build_model(cfg)
    axes = model.axes()
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(seed))
    p_shard = _tree_shardings(rules, params_s, axes)
    step_fn = build_train_step(model, rules, run, accum=1)
    return model, rules, p_shard, jax.jit(step_fn, donate_argnums=(0, 1, 3))


def run_steps(mesh, jit_step, params, opt, ds, b_shard, start, n):
    losses = []
    with mesh:
        for s in range(start, start + n):
            batch = {"tokens": jax.device_put(ds.batch(s), b_shard)}
            params, opt, _err, m = jit_step(params, opt, batch, None)
            losses.append(float(m["loss"]))
    return params, opt, losses


def main():
    assert jax.device_count() == 8
    cfg = get_smoke_config("h2o_danube_1_8b")
    run = RunConfig(lr=1e-3, total_steps=12, warmup_steps=2)
    ds = SyntheticTokens(TokenDatasetConfig(vocab=cfg.vocab, seq_len=32,
                                            global_batch=8, seed=0))

    # --- phase 1: full fleet (2, 4) = (data, model) -----------------------
    plan_a = plan_mesh(8, global_batch=8, want_model=4)
    mesh_a = make_mesh_from_plan(plan_a)
    model, rules_a, pshard_a, step_a = setup(mesh_a, cfg, run)
    with mesh_a:
        params = jax.jit(model.init, out_shardings=pshard_a)(
            jax.random.PRNGKey(0))
        opt = adamw_init(params)
    bshard_a = rules_a.sharding(("batch", "seq"), (8, 32))
    params, opt, losses_a = run_steps(mesh_a, step_a, params, opt, ds,
                                      bshard_a, 0, 6)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(6, (params, opt))

        # reference: continue on the same mesh without restarting
        p_ref, o_ref, losses_ref = run_steps(mesh_a, step_a, params, opt, ds,
                                             bshard_a, 6, 3)

        # --- phase 2: survivor fleet (4, 2) — different mesh shape --------
        plan_b = plan_mesh(8, global_batch=8, want_model=2)
        mesh_b = make_mesh_from_plan(plan_b)
        assert tuple(mesh_b.shape.values()) != tuple(mesh_a.shape.values())
        model_b, rules_b, pshard_b, step_b = setup(mesh_b, cfg, run)
        with mesh_b:
            p_s = jax.eval_shape(model_b.init, jax.random.PRNGKey(0))
            template = (p_s, jax.eval_shape(adamw_init, p_s))
            (params_b, opt_b), step_no, _ = mgr.restore(template)
            params_b = jax.device_put(params_b, pshard_b)  # reshard
        assert step_no == 6
        bshard_b = rules_b.sharding(("batch", "seq"), (8, 32))
        _, _, losses_b = run_steps(mesh_b, step_b, params_b, opt_b, ds,
                                   bshard_b, 6, 3)

    np.testing.assert_allclose(losses_b, losses_ref, rtol=2e-4, atol=1e-5)
    print(json.dumps({"ok": True, "losses_pre": losses_a,
                      "losses_resumed": losses_b,
                      "losses_reference": losses_ref}))


if __name__ == "__main__":
    main()
