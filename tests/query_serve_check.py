"""Subprocess body for the owner-routed query-engine parity test (needs
its own jax init with fake devices — run via tests/test_distributed.py,
never imported by pytest).

Checks, against the single-device :class:`QueryEngine` ground truth:
  1. the 8-device :class:`RoutedQueryEngine` answers a mixed batch
     (degree / adjacency / PageRank / triangle) bit-identically
     (``np.array_equal``, not allclose — the psum merges disjoint one-hot
     contributions, so routing must cost zero ulps);
  2. the full PageRank block vector and triangle scalar are bit-identical;
  3. the routing table actually spreads blocks across devices (the test
     would pass trivially if everything routed to device 0);
  4. elastic shrink: rebuilding the engine on a 4-device survivor mesh
     (a routing-table rebuild — the owner hash depends only on device
     count + salt) re-routes every block and stays bit-identical;
  5. the :class:`QueryServer` scheduler drives the routed engine to the
     same answers as the local engine, request by request.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json

import jax
import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.queries_jax import (
    KIND_ADJACENCY,
    KIND_DEGREE,
    KIND_PAGERANK,
    KIND_TRIANGLE,
    QueryEngine,
    RoutedQueryEngine,
)
from repro.graphs import generate
from repro.launch.mesh import make_host_mesh
from repro.launch.query_serve import QueryServer, random_workload


def check_parity(local: QueryEngine, routed: RoutedQueryEngine, v: int,
                 label: str) -> None:
    rng = np.random.default_rng(42)
    b = 64
    kinds = np.array([KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK,
                      KIND_TRIANGLE] * (b // 4), np.int32)
    u = rng.integers(0, v, b).astype(np.int32)
    w = rng.integers(0, v, b).astype(np.int32)
    want = local.answer_batch(kinds, u, w)
    got = routed.answer_batch(kinds, u, w)
    assert np.array_equal(want, got), (
        f"{label}: routed batch differs, "
        f"maxdiff={np.abs(want - got).max()}")
    assert np.array_equal(np.asarray(local.pagerank_blocks()),
                          np.asarray(routed.pagerank_blocks())), (
        f"{label}: PageRank block vector differs")
    assert local.triangle_density() == routed.triangle_density(), label


def check_serving(local: QueryEngine, routed: RoutedQueryEngine,
                  v: int) -> int:
    rng = np.random.default_rng(3)
    reqs = random_workload(rng, v, 50,
                           [KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK])
    srv_l = QueryServer(local, slots=16)
    srv_r = QueryServer(routed, slots=16)
    for r in reqs:
        srv_l.submit(dataclasses.replace(r))
        srv_r.submit(dataclasses.replace(r))
    while srv_l.step():
        pass
    while srv_r.step():
        pass
    al = {r.rid: r.answer for r in srv_l.done}
    ar = {r.rid: r.answer for r in srv_r.done}
    assert al == ar, "served answers differ between local and routed"
    return len(al)


def main():
    assert jax.device_count() == 8
    src, dst, v = generate("ego-facebook", seed=2, scale=0.06)
    res = summarize(src, dst, v, SummaryConfig(T=8, k_frac=0.4, seed=2),
                    collect_history=False)
    local = QueryEngine(res)

    # ---- 8-device mesh (2 axes: psum + axis_index over a tuple) ---------
    mesh8 = make_host_mesh((2, 4), ("data", "model"))
    routed8 = RoutedQueryEngine(res, mesh8)
    counts8 = routed8.owner_counts()
    assert counts8.sum() == res.num_supernodes
    assert (counts8 > 0).sum() > 1, f"degenerate routing table: {counts8}"
    check_parity(local, routed8, v, "mesh(2,4)")
    served = check_serving(local, routed8, v)

    # ---- elastic shrink 8 -> 4: rebuild the engine on the survivors -----
    survivors = np.array(jax.devices()[:4]).reshape(4)
    mesh4 = jax.sharding.Mesh(survivors, ("data",))
    routed4 = RoutedQueryEngine(res, mesh4)
    counts4 = routed4.owner_counts()
    assert counts4.shape == (4,), counts4.shape
    assert (counts4 > 0).sum() > 1, f"degenerate 4-dev table: {counts4}"
    # the hash re-draw must actually move blocks (count changed 8 -> 4)
    assert not np.array_equal(counts8[:4], counts4), \
        "shrink did not rebuild the routing table"
    check_parity(local, routed4, v, "mesh(4,) after shrink")

    print(json.dumps({
        "ok": True, "devices": jax.device_count(), "V": v,
        "num_supernodes": res.num_supernodes,
        "num_superedges": res.num_superedges,
        "routed_devices_8": int((counts8 > 0).sum()),
        "routed_devices_4": int((counts4 > 0).sum()),
        "served": served,
    }))


if __name__ == "__main__":
    main()
