"""Subprocess body for the owner-routed query-engine parity test (needs
its own jax init with fake devices — run via tests/test_distributed.py,
never imported by pytest).

Checks, against the single-device :class:`QueryEngine` ground truth:
  1. the 8-device :class:`RoutedQueryEngine` answers a mixed batch over
     every query kind (degree / adjacency / PageRank / triangle / k-hop /
     cut / conductance) bit-identically (``np.array_equal``, not allclose
     — the psum merges disjoint one-hot contributions, so routing must
     cost zero ulps);
  2. the full PageRank block vector and triangle scalar are bit-identical;
  3. the routing table actually spreads blocks across devices (the test
     would pass trivially if everything routed to device 0);
  4. elastic shrink: rebuilding the engine on a 4-device survivor mesh
     (a routing-table rebuild — the owner hash depends only on device
     count + salt) re-routes every block and stays bit-identical;
  5. the :class:`QueryServer` scheduler drives the routed engine to the
     same answers as the local engine, request by request;
  6. the memory-partitioned :class:`PartitionedQueryEngine` (each device
     holds only its owned rows + halo tables — DESIGN.md §16) is
     bit-identical to both tiers for every kind, on the 8-device mesh
     with a demonstrably non-trivial partition (>1 owner, non-empty
     halo), after an 8→4 shrink that rebuilds the halo tables, and with
     a forced second-hop route (``dense_row_nnz`` low enough that dense
     rows leave the resident halo);
  7. per-device memory accounting: resident bytes (owned rows + halo)
     stay strictly below the replicated tier's full row storage.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json

import jax
import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.queries_jax import (
    KIND_ADJACENCY,
    KIND_CONDUCTANCE,
    KIND_CUT,
    KIND_DEGREE,
    KIND_KHOP,
    KIND_PAGERANK,
    KIND_TRIANGLE,
    PartitionedQueryEngine,
    QueryEngine,
    RoutedQueryEngine,
    pack_set_counts,
)
from repro.graphs import generate
from repro.launch.mesh import make_host_mesh
from repro.launch.query_serve import QueryServer, random_workload


def _mixed_batch(v: int, b: int = 63, seed: int = 42):
    """One batch cycling through every query kind, sets included."""
    rng = np.random.default_rng(seed)
    cycle = [KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK, KIND_TRIANGLE,
             KIND_KHOP, KIND_CUT, KIND_CONDUCTANCE]
    kinds = np.array([cycle[i % len(cycle)] for i in range(b)], np.int32)
    u = rng.integers(0, v, b).astype(np.int32)
    w = rng.integers(0, v, b).astype(np.int32)
    w[kinds == KIND_KHOP] = rng.integers(0, 6, (kinds == KIND_KHOP).sum())
    sets_a = [None] * b
    sets_b = [None] * b
    for s in range(b):
        if kinds[s] in (KIND_CUT, KIND_CONDUCTANCE):
            sets_a[s] = rng.choice(v, size=int(rng.integers(1, v // 3)),
                                   replace=False)
        if kinds[s] == KIND_CUT:
            sets_b[s] = rng.choice(v, size=int(rng.integers(1, v // 3)),
                                   replace=False)
    return kinds, u, w, sets_a, sets_b


def check_parity(local: QueryEngine, other, v: int, label: str) -> None:
    kinds, u, w, sets_a, sets_b = _mixed_batch(v)
    ca, cb, ov = pack_set_counts(local.bs, kinds, sets_a, sets_b)
    want = local.answer_batch(kinds, u, w, ca, cb, ov)
    got = other.answer_batch(kinds, u, w, ca, cb, ov)
    assert np.array_equal(want, got), (
        f"{label}: batch differs, "
        f"maxdiff={np.abs(want - got).max()}")
    assert np.array_equal(np.asarray(local.pagerank_blocks()),
                          np.asarray(other.pagerank_blocks())), (
        f"{label}: PageRank block vector differs")
    assert local.triangle_density() == other.triangle_density(), label


def check_serving(local: QueryEngine, routed, v: int) -> int:
    rng = np.random.default_rng(3)
    reqs = random_workload(rng, v, 50,
                           [KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK,
                            KIND_KHOP, KIND_CUT, KIND_CONDUCTANCE])
    srv_l = QueryServer(local, slots=16)
    srv_r = QueryServer(routed, slots=16)
    for r in reqs:
        srv_l.submit(dataclasses.replace(r))
        srv_r.submit(dataclasses.replace(r))
    while srv_l.step():
        pass
    while srv_r.step():
        pass
    al = {r.rid: r.answer for r in srv_l.done}
    ar = {r.rid: r.answer for r in srv_r.done}
    assert al == ar, "served answers differ between local and routed"
    return len(al)


def main():
    assert jax.device_count() == 8
    src, dst, v = generate("ego-facebook", seed=2, scale=0.06)
    res = summarize(src, dst, v, SummaryConfig(T=8, k_frac=0.4, seed=2),
                    collect_history=False)
    local = QueryEngine(res)

    # ---- 8-device mesh (2 axes: psum + axis_index over a tuple) ---------
    mesh8 = make_host_mesh((2, 4), ("data", "model"))
    routed8 = RoutedQueryEngine(res, mesh8)
    counts8 = routed8.owner_counts()
    assert counts8.sum() == res.num_supernodes
    assert (counts8 > 0).sum() > 1, f"degenerate routing table: {counts8}"
    check_parity(local, routed8, v, "mesh(2,4)")
    served = check_serving(local, routed8, v)

    # ---- elastic shrink 8 -> 4: rebuild the engine on the survivors -----
    survivors = np.array(jax.devices()[:4]).reshape(4)
    mesh4 = jax.sharding.Mesh(survivors, ("data",))
    routed4 = RoutedQueryEngine(res, mesh4)
    counts4 = routed4.owner_counts()
    assert counts4.shape == (4,), counts4.shape
    assert (counts4 > 0).sum() > 1, f"degenerate 4-dev table: {counts4}"
    # the hash re-draw must actually move blocks (count changed 8 -> 4)
    assert not np.array_equal(counts8[:4], counts4), \
        "shrink did not rebuild the routing table"
    check_parity(local, routed4, v, "mesh(4,) after shrink")

    # ---- partitioned tier: sharded rows + halo exchange (DESIGN.md §16) --
    part8 = PartitionedQueryEngine(res, mesh8)
    stats8 = part8.partition_stats()
    owner_counts = np.asarray(stats8["owner_counts"])
    assert owner_counts.sum() == res.num_supernodes
    assert (owner_counts > 0).sum() > 1, (
        f"degenerate partition: {owner_counts}")
    halo_max = int(max(stats8["halo_counts"]))
    assert halo_max > 0, "partition has no cross-device references; " \
        "the halo exchange is untested"
    # per-device memory: owned rows + halo strictly below full row storage
    resident = int(stats8["resident_bytes_per_device"])
    replicated = int(stats8["replicated_row_bytes"])
    assert resident < replicated, (resident, replicated)
    check_parity(local, part8, v, "partitioned mesh(2,4)")
    # partitioned == routed too (same batch, independent code paths)
    kinds, u, w, sets_a, sets_b = _mixed_batch(v)
    ca, cb, ov = pack_set_counts(local.bs, kinds, sets_a, sets_b)
    assert np.array_equal(
        routed8.answer_batch(kinds, u, w, ca, cb, ov),
        part8.answer_batch(kinds, u, w, ca, cb, ov)), \
        "partitioned differs from routed"
    served_part = check_serving(local, part8, v)

    # forced second-hop route: a low dense threshold evicts dense rows
    # from every resident halo — answers must not move a bit
    dense8 = PartitionedQueryEngine(res, mesh8, dense_row_nnz=2)
    dstats = dense8.partition_stats()
    assert dstats["dense_rows"] > 0, "threshold evicted no rows"
    check_parity(local, dense8, v, "partitioned mesh(2,4) second-hop")

    # elastic shrink 8 -> 4: halo tables rebuilt for the survivor mesh
    part4 = PartitionedQueryEngine(res, mesh4)
    stats4 = part4.partition_stats()
    assert len(stats4["owner_counts"]) == 4
    assert not np.array_equal(np.asarray(stats4["owner_counts"]),
                              owner_counts[:4]), \
        "shrink did not repartition the rows"
    check_parity(local, part4, v, "partitioned mesh(4,) after shrink")

    print(json.dumps({
        "ok": True, "devices": jax.device_count(), "V": v,
        "num_supernodes": res.num_supernodes,
        "num_superedges": res.num_superedges,
        "routed_devices_8": int((counts8 > 0).sum()),
        "routed_devices_4": int((counts4 > 0).sum()),
        "served": served,
        "partitioned_ok": True,
        "partitioned_devices_8": int((owner_counts > 0).sum()),
        "halo_max": halo_max,
        "resident_bytes_per_device": resident,
        "replicated_row_bytes": replicated,
        "dense_rows": int(dstats["dense_rows"]),
        "served_partitioned": served_part,
    }))


if __name__ == "__main__":
    main()
