"""SummaryEngine regression against pre-refactor golden metrics.

``tests/golden/engine_local.json`` was recorded from the straight-line
one-round-per-dispatch driver the engine replaced: every per-round history
metric and the final ``SummaryResult`` must stay bit-identical through the
while_loop-chunked driver, for any chunk size (``driver_chunk=1`` is the
history-equivalent sync-every-round mode; the distributed analogue lives in
``tests/dist_check.py``).
"""

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core import SummaryConfig, summarize
from repro.core.engine import (
    EngineCheckpointer,
    FingerprintMismatch,
    LocalBackend,
    SummaryEngine,
    theta_schedule_host,
)
from repro.graphs import generate
from repro.runtime import (
    CheckpointManager,
    Preempted,
    PreemptionGuard,
    StragglerMonitor,
)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "engine_local.json"

HISTORY_KEYS = ("size_bits", "re1", "re2", "nmerges", "num_supernodes",
                "num_superedges", "mdl_cost", "t", "theta")


def _load():
    g = json.loads(GOLDEN.read_text())
    fx, cfg = g["fixture"], g["config"]
    src, dst, v = generate(fx["dataset"], seed=fx["gen_seed"],
                           scale=fx["scale"])
    assert v == fx["V"]
    return src, dst, v, cfg, g


@pytest.mark.parametrize("driver_chunk", [8, 1, 3])
def test_local_engine_matches_golden(driver_chunk):
    src, dst, v, cfg_d, g = _load()
    cfg = SummaryConfig(T=cfg_d["T"], k_frac=cfg_d["k_frac"],
                        seed=cfg_d["seed"], driver_chunk=driver_chunk)
    res = summarize(src, dst, v, cfg)

    assert len(res.history) == len(g["history"])
    for got, want in zip(res.history, g["history"]):
        for k in HISTORY_KEYS:
            assert got[k] == want[k], (driver_chunk, got["t"], k,
                                       got[k], want[k])

    final = g["final"]
    assert res.size_bits == final["size_bits"]
    assert res.input_size_bits == final["input_size_bits"]
    assert res.re1 == final["re1"]
    assert res.re2 == final["re2"]
    assert res.mdl_cost == final["mdl_cost"]
    assert res.num_supernodes == final["num_supernodes"]
    assert res.num_superedges == final["num_superedges"]
    assert res.iterations_run == final["iterations_run"]
    assert int(np.sum(res.node2super)) == final["node2super_sum"]
    assert int(np.sum(res.edge_w)) == final["edge_w_sum"]


def test_theta_schedule_host_matches_paper():
    # Eq. (21): θ(t) = (1+t)⁻¹ before the last round, 0 at t = T
    assert theta_schedule_host(1, 10) == 0.5
    assert theta_schedule_host(9, 10) == 0.1
    assert theta_schedule_host(10, 10) == 0.0


def test_engine_run_payload_consistent():
    """EngineRun bookkeeping: k_bits, last_stats, and history agree."""
    src, dst, v, cfg_d, _ = _load()
    cfg = SummaryConfig(T=4, k_frac=0.3, seed=1)
    backend = LocalBackend(src, dst, v, cfg)
    run = SummaryEngine(backend).run()
    assert run.k_bits == cfg.target_bits(run.input_size_bits)
    assert run.iterations_run == len(run.history)
    assert run.last_stats is not None
    for k in backend.stat_keys:
        assert run.last_stats[k] == run.history[-1][k]
    assert run.sparsify_wall_s >= 0.0
    assert "after" in run.finalize
    # one dispatch per chunk, all timed; no checkpointer/monitor → zeros
    assert len(run.chunk_wall_s) == 1 and run.chunk_wall_s[0] > 0.0
    assert run.straggler_events == []
    assert run.resumed_from is None and run.checkpoint_saves == 0


# ---------------------------------------------------------------------------
# checkpoint / resume (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _ckp(tmp_path, name="ck", **kw):
    return EngineCheckpointer(
        manager=CheckpointManager(str(tmp_path / name), keep=kw.pop("keep", 50)),
        **kw)


def _drop_steps_after(mgr, keep_step):
    import shutil

    for s in mgr.all_steps():
        if s > keep_step:
            shutil.rmtree(pathlib.Path(mgr.dir) / f"step_{s:010d}")


@pytest.mark.parametrize("resume_chunk", [3, 1, 8])
def test_local_resume_bit_identical(tmp_path, resume_chunk):
    """Kill-at-any-chunk-boundary → resume ≡ the uninterrupted run.

    The golden is a plain run; the interrupted run checkpoints every
    chunk, everything after the *first* committed step is deleted
    (equivalent to dying right after that boundary), and the resume —
    even under a different ``driver_chunk`` — must reproduce every final
    metric and the partition bit-for-bit.
    """
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=10, k_frac=0.2, seed=0, driver_chunk=3)
    golden = summarize(src, dst, v, cfg)

    ck = _ckp(tmp_path, every=1)
    full = summarize(src, dst, v, cfg, checkpointer=ck)
    assert full.checkpoint_saves >= 2
    steps = ck.manager.all_steps()
    assert steps, "no committed checkpoints"
    _drop_steps_after(ck.manager, steps[0])

    cfg_r = dataclasses.replace(cfg, driver_chunk=resume_chunk)
    ck2 = _ckp(tmp_path, every=1)
    res = summarize(src, dst, v, cfg_r, checkpointer=ck2, resume=True)
    assert res.resumed_from == steps[0]
    for k in ("size_bits", "input_size_bits", "re1", "re2", "mdl_cost",
              "num_supernodes", "num_superedges", "iterations_run"):
        assert getattr(res, k) == getattr(golden, k), k
    np.testing.assert_array_equal(res.node2super, golden.node2super)
    np.testing.assert_array_equal(res.super_size, golden.super_size)
    np.testing.assert_array_equal(res.edge_w, golden.edge_w)
    # resumed history continues the golden's round numbering seamlessly
    assert [h["t"] for h in golden.history] == \
        list(range(1, golden.iterations_run + 1))
    got_hist = [{k: h[k] for k in HISTORY_KEYS} for h in res.history]
    want_hist = [{k: h[k] for k in HISTORY_KEYS} for h in golden.history]
    assert got_hist == want_hist


def test_resume_from_final_phase_skips_merging(tmp_path):
    """A crash inside the sparsify tail resumes straight to finalize."""
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=4, k_frac=0.3, seed=0)
    golden = summarize(src, dst, v, cfg)

    ck = _ckp(tmp_path, every=0)  # only the merge-done (phase=final) save
    full = summarize(src, dst, v, cfg, checkpointer=ck)
    assert full.checkpoint_saves == 1
    ck2 = _ckp(tmp_path, every=0)
    res = summarize(src, dst, v, cfg, checkpointer=ck2, resume=True)
    assert res.resumed_from == golden.iterations_run
    # no merge rounds re-ran: the only dispatches were... none at all
    assert res.chunk_wall_s == []
    assert res.size_bits == golden.size_bits
    np.testing.assert_array_equal(res.node2super, golden.node2super)


def test_resume_fingerprint_gate(tmp_path):
    """A checkpoint is only resumable under the identical config+graph —
    except ``driver_chunk``, whose bit-identity across values is proven."""
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=4, k_frac=0.3, seed=0)
    ck = _ckp(tmp_path, every=1)
    summarize(src, dst, v, cfg, checkpointer=ck)

    with pytest.raises(FingerprintMismatch, match="config"):
        summarize(src, dst, v, dataclasses.replace(cfg, group_size=16),
                  checkpointer=_ckp(tmp_path, every=1), resume=True)
    with pytest.raises(FingerprintMismatch, match="graph"):
        summarize(src[:-10], dst[:-10], v,
                  cfg, checkpointer=_ckp(tmp_path, every=1), resume=True)
    with pytest.raises(FingerprintMismatch, match="graph"):
        summarize(src, dst, v, cfg, resume=True,
                  checkpointer=_ckp(tmp_path, every=1,
                                    graph_extra={"dataset": "other"}))
    # driver_chunk is exempt: this must NOT raise
    res = summarize(src, dst, v, dataclasses.replace(cfg, driver_chunk=1),
                    checkpointer=_ckp(tmp_path, every=1), resume=True)
    assert res.resumed_from is not None


def test_resume_with_empty_dir_starts_fresh(tmp_path):
    """``--resume`` against a dir with nothing committed is a cold start,
    not an error — the idempotent supervisor retry loop depends on it."""
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=3, k_frac=0.3, seed=0)
    golden = summarize(src, dst, v, cfg)
    res = summarize(src, dst, v, cfg, checkpointer=_ckp(tmp_path, every=1),
                    resume=True)
    assert res.resumed_from is None
    assert res.size_bits == golden.size_bits


def test_resume_requires_checkpointer():
    src, dst, v, _, _ = _load()
    backend = LocalBackend(src, dst, v, SummaryConfig(T=2))
    with pytest.raises(ValueError, match="checkpointer"):
        SummaryEngine(backend).run(resume=True)


def test_preemption_saves_and_raises(tmp_path):
    """A pending signal is honored at the next host-sync point: the state
    is saved synchronously and ``Preempted`` carries the committed step."""
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=10, k_frac=0.2, seed=0, driver_chunk=2)
    guard = PreemptionGuard(signals=())
    guard._requested = True  # signal already pending before the run
    ck = _ckp(tmp_path, every=1, guard=guard)
    with pytest.raises(Preempted) as ei:
        summarize(src, dst, v, cfg, checkpointer=ck)
    assert ei.value.step == 2  # first chunk boundary
    assert ck.manager.latest_step() == 2

    golden = summarize(src, dst, v, cfg)
    res = summarize(src, dst, v, cfg, checkpointer=_ckp(tmp_path, every=1),
                    resume=True)
    assert res.resumed_from == 2
    assert res.size_bits == golden.size_bits
    np.testing.assert_array_equal(res.node2super, golden.node2super)


def test_straggler_monitor_brackets_dispatches():
    src, dst, v, _, _ = _load()
    cfg = SummaryConfig(T=6, k_frac=0.2, seed=0, driver_chunk=2)
    mon = StragglerMonitor(warmup_steps=1000)  # never flags
    res = summarize(src, dst, v, cfg, monitor=mon)
    assert mon.count == len(res.chunk_wall_s) > 0
    assert res.straggler_events == []
