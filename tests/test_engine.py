"""SummaryEngine regression against pre-refactor golden metrics.

``tests/golden/engine_local.json`` was recorded from the straight-line
one-round-per-dispatch driver the engine replaced: every per-round history
metric and the final ``SummaryResult`` must stay bit-identical through the
while_loop-chunked driver, for any chunk size (``driver_chunk=1`` is the
history-equivalent sync-every-round mode; the distributed analogue lives in
``tests/dist_check.py``).
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core import SummaryConfig, summarize
from repro.core.engine import LocalBackend, SummaryEngine, theta_schedule_host
from repro.graphs import generate

GOLDEN = pathlib.Path(__file__).parent / "golden" / "engine_local.json"

HISTORY_KEYS = ("size_bits", "re1", "re2", "nmerges", "num_supernodes",
                "num_superedges", "mdl_cost", "t", "theta")


def _load():
    g = json.loads(GOLDEN.read_text())
    fx, cfg = g["fixture"], g["config"]
    src, dst, v = generate(fx["dataset"], seed=fx["gen_seed"],
                           scale=fx["scale"])
    assert v == fx["V"]
    return src, dst, v, cfg, g


@pytest.mark.parametrize("driver_chunk", [8, 1, 3])
def test_local_engine_matches_golden(driver_chunk):
    src, dst, v, cfg_d, g = _load()
    cfg = SummaryConfig(T=cfg_d["T"], k_frac=cfg_d["k_frac"],
                        seed=cfg_d["seed"], driver_chunk=driver_chunk)
    res = summarize(src, dst, v, cfg)

    assert len(res.history) == len(g["history"])
    for got, want in zip(res.history, g["history"]):
        for k in HISTORY_KEYS:
            assert got[k] == want[k], (driver_chunk, got["t"], k,
                                       got[k], want[k])

    final = g["final"]
    assert res.size_bits == final["size_bits"]
    assert res.input_size_bits == final["input_size_bits"]
    assert res.re1 == final["re1"]
    assert res.re2 == final["re2"]
    assert res.mdl_cost == final["mdl_cost"]
    assert res.num_supernodes == final["num_supernodes"]
    assert res.num_superedges == final["num_superedges"]
    assert res.iterations_run == final["iterations_run"]
    assert int(np.sum(res.node2super)) == final["node2super_sum"]
    assert int(np.sum(res.edge_w)) == final["edge_w_sum"]


def test_theta_schedule_host_matches_paper():
    # Eq. (21): θ(t) = (1+t)⁻¹ before the last round, 0 at t = T
    assert theta_schedule_host(1, 10) == 0.5
    assert theta_schedule_host(9, 10) == 0.1
    assert theta_schedule_host(10, 10) == 0.0


def test_engine_run_payload_consistent():
    """EngineRun bookkeeping: k_bits, last_stats, and history agree."""
    src, dst, v, cfg_d, _ = _load()
    cfg = SummaryConfig(T=4, k_frac=0.3, seed=1)
    backend = LocalBackend(src, dst, v, cfg)
    run = SummaryEngine(backend).run()
    assert run.k_bits == cfg.target_bits(run.input_size_bits)
    assert run.iterations_run == len(run.history)
    assert run.last_stats is not None
    for k in backend.stat_keys:
        assert run.last_stats[k] == run.history[-1][k]
    assert run.sparsify_wall_s >= 0.0
    assert "after" in run.finalize
