"""Chaos-injection harness: kill the real launcher at seeded checkpoint
steps, resume, and assert launcher-JSON bit-identity with an uninterrupted
golden run.

    PYTHONPATH=src python tests/chaos_check.py \
        --edge-list data/rmat_1m.txt.gz --T 15 --driver-chunk 1 \
        --distributed --devices 8 \
        --kill TERM:2 --kill KILL:5 --out chaos_report.json

Per ``--kill SIG:STEP`` scenario, the harness launches
``repro.launch.summarize`` with ``--checkpoint-dir``, SIGSTOP-samples the
child until checkpoint ``STEP`` is committed (freeze → inspect → decide,
so the kill lands at a known boundary instead of racing the round loop),
delivers the signal, then reruns the identical command with ``--resume``
— on ``--resume-devices`` survivors when testing the elastic 8→N shrink.

Outcome contract per signal:

  TERM — cooperative: the launcher saves at the next host-sync point,
         prints ``{"preempted": true, ...}``, exits ``RESUMABLE_EXIT``
         (75). If the signal lands after the last sync point the run just
         finishes (rc 0) — recorded as ``completed`` and compared to the
         golden directly.
  KILL — no grace: the process dies with ``-SIGKILL``; the latest
         *committed* checkpoint is the resume point and any ``.tmp-``
         half-write is ignored.

Comparison: every metric key the launcher prints must equal the golden
**bit-for-bit** (same device count). Across a device shrink the merge
trajectory is still identical (integer state, exact pair aggregation) so
counts stay exact, but the psum partial-sum grouping of the RE reductions
is mesh-shaped — those keys are compared against a same-device-count
golden exactly and against the original-mesh golden to 1e-6.

The harness never imports jax — each launcher subprocess owns its device
topology via XLA_FLAGS.
"""

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESUMABLE_EXIT = 75  # repro.runtime.RESUMABLE_EXIT (harness is jax-free)

#: launcher JSON keys that must be bit-identical on the same device count
EXACT_KEYS = ("V", "E", "size_bits", "size_bits_before_sparsify",
              "relative_size", "re1", "re2", "num_supernodes",
              "num_superedges", "superedges_dropped", "iterations")
#: keys exact across a device shrink too (mesh-independent integers)
CROSS_MESH_EXACT = ("V", "E", "num_supernodes", "num_superedges",
                    "superedges_dropped", "iterations")
#: float keys compared to tolerance across a shrink (psum grouping)
CROSS_MESH_APPROX = ("size_bits", "size_bits_before_sparsify",
                     "relative_size", "re1", "re2")


def launcher_cmd(args, ckdir=None, resume=False):
    cmd = [sys.executable, "-m", "repro.launch.summarize",
           "--k-frac", str(args.k_frac), "--T", str(args.T),
           "--seed", str(args.seed),
           "--group-size", str(args.group_size),
           "--driver-chunk", str(args.driver_chunk)]
    if args.edge_list:
        cmd += ["--edge-list", args.edge_list]
    else:
        cmd += ["--dataset", args.dataset, "--scale", str(args.scale)]
    if args.chunk_edges:
        cmd += ["--chunk-edges", str(args.chunk_edges)]
    if args.distributed:
        cmd += ["--distributed"]
    if ckdir:
        cmd += ["--checkpoint-dir", ckdir,
                "--checkpoint-every", str(args.checkpoint_every),
                "--checkpoint-keep", str(args.checkpoint_keep)]
    if resume:
        cmd += ["--resume"]
    return cmd


def env_for(devices):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    else:
        env.pop("XLA_FLAGS", None)
    return env


def committed_steps(ckdir):
    if not os.path.isdir(ckdir):
        return []
    out = []
    for name in os.listdir(ckdir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckdir, name, "COMMIT")):
            out.append(int(name[len("step_"):]))
    return sorted(out)


def checkpoint_bytes(ckdir):
    """On-disk footprint of the largest committed checkpoint."""
    best = 0
    for s in committed_steps(ckdir):
        d = os.path.join(ckdir, f"step_{s:010d}")
        best = max(best, sum(os.path.getsize(os.path.join(d, f))
                             for f in os.listdir(d)))
    return best


def last_json(stdout):
    for line in reversed(stdout.strip().splitlines()):
        line = line.strip()
        if line.endswith("}"):
            # launcher output is an indented multi-line object; find its
            # opening line and parse the span
            text = stdout[: stdout.rindex(line) + len(line)]
            start = text.rindex("\n{") if "\n{" in text else text.index("{")
            return json.loads(text[start:])
    raise ValueError(f"no JSON object in stdout:\n{stdout}")


def run_to_completion(cmd, env, timeout):
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(
            f"launcher failed rc={out.returncode}\ncmd: {' '.join(cmd)}\n"
            f"stderr:\n{out.stderr[-4000:]}")
    return last_json(out.stdout)


def kill_at_step(cmd, env, ckdir, step, signame, timeout):
    """Run ``cmd``; deliver ``signame`` once checkpoint ``step`` commits.

    SIGSTOP-samples the child so "is step N committed while the run is
    still going" is decided on a frozen process — the only way to miss the
    window is a commit-to-exit gap shorter than one poll interval.
    Returns ``(returncode, delivered, stdout, stderr)``.
    """
    sig = {"TERM": signal.SIGTERM, "KILL": signal.SIGKILL}[signame]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    delivered = False
    deadline = time.time() + timeout
    try:
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            os.kill(proc.pid, signal.SIGSTOP)
            try:
                steps = committed_steps(ckdir)
                if steps and steps[-1] >= step:
                    os.kill(proc.pid, sig)
                    delivered = True
            finally:
                if proc.poll() is None and sig != signal.SIGKILL:
                    os.kill(proc.pid, signal.SIGCONT)
                elif not delivered and proc.poll() is None:
                    os.kill(proc.pid, signal.SIGCONT)
            if delivered:
                break
            time.sleep(0.002)
        out, err = proc.communicate(timeout=timeout)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    return proc.returncode, delivered, out, err


def compare(got, want, exact, approx=(), rtol=1e-6):
    """Mismatch list (empty = pass); keys absent from both are skipped."""
    bad = []
    for k in exact:
        if k not in want and k not in got:
            continue
        if got.get(k) != want.get(k):
            bad.append(f"{k}: got {got.get(k)!r} want {want.get(k)!r}")
    for k in approx:
        if k not in want and k not in got:
            continue
        g, w = got.get(k), want.get(k)
        if g is None or w is None or \
                abs(g - w) > rtol * max(abs(g), abs(w), 1e-30):
            bad.append(f"{k} (≈): got {g!r} want {w!r}")
    return bad


def main():
    ap = argparse.ArgumentParser(
        description="fault-injection gate for checkpoint/resume")
    ap.add_argument("--dataset", default="dblp")
    ap.add_argument("--edge-list", default=None)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--chunk-edges", type=int, default=None)
    ap.add_argument("--driver-chunk", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--checkpoint-keep", type=int, default=3)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="XLA host-platform device count for every run")
    ap.add_argument("--resume-devices", type=int, default=None,
                    help="resume on this many devices instead (elastic "
                         "shrink); adds a same-count golden for the "
                         "bit-identity comparison")
    ap.add_argument("--kill", action="append", default=[],
                    metavar="SIG:STEP",
                    help="scenario: deliver SIG (TERM|KILL) once "
                         "checkpoint STEP is committed (repeatable)")
    ap.add_argument("--workdir", default=None,
                    help="scratch dir (default: a fresh temp dir)")
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--out", default=None,
                    help="also write the report JSON here (CI artifact)")
    args = ap.parse_args()
    if not args.kill:
        args.kill = ["TERM:2", "KILL:2"]

    workdir = args.workdir or os.path.join(
        os.environ.get("TMPDIR", "/tmp"), f"chaos_{os.getpid()}")
    os.makedirs(workdir, exist_ok=True)

    env = env_for(args.devices)
    golden = run_to_completion(launcher_cmd(args), env, args.timeout)
    shrink = args.resume_devices is not None and \
        args.resume_devices != args.devices
    golden_shrunk = None
    if shrink:
        golden_shrunk = run_to_completion(
            launcher_cmd(args), env_for(args.resume_devices), args.timeout)

    report = {"ok": True, "golden": golden, "scenarios": [],
              "checkpoint_bytes": 0}
    for spec in args.kill:
        signame, step_s = spec.split(":")
        step = int(step_s)
        ckdir = os.path.join(workdir, f"ck_{signame}_{step}")
        shutil.rmtree(ckdir, ignore_errors=True)
        scen = {"signal": signame, "kill_step": step, "errors": []}
        rc, delivered, out, err = kill_at_step(
            launcher_cmd(args, ckdir=ckdir), env, ckdir, step, signame,
            args.timeout)
        scen["kill_rc"] = rc
        scen["delivered"] = delivered
        report["checkpoint_bytes"] = max(report["checkpoint_bytes"],
                                         checkpoint_bytes(ckdir))
        if not delivered:
            scen["errors"].append(
                f"run finished (rc={rc}) before step {step} committed — "
                f"kill step too late for this workload")
        elif signame == "KILL":
            if rc != -signal.SIGKILL:
                scen["errors"].append(f"SIGKILL rc {rc} != -9")
        elif rc == 0:
            # TERM landed after the last sync point; the run completed
            scen["outcome"] = "completed"
            scen["errors"].extend(compare(last_json(out), golden,
                                          EXACT_KEYS))
        else:
            if rc != RESUMABLE_EXIT:
                scen["errors"].append(
                    f"SIGTERM rc {rc} != {RESUMABLE_EXIT}\n{err[-2000:]}")
            else:
                rec = last_json(out)
                if not rec.get("preempted"):
                    scen["errors"].append(f"no preempted record: {rec}")
                scen["preempt_step"] = rec.get("checkpoint_step")

        if delivered and rc != 0 and not scen["errors"]:
            if not committed_steps(ckdir):
                scen["errors"].append("no committed checkpoint to resume")
            else:
                scen["resume_from"] = committed_steps(ckdir)[-1]
                r_env = env_for(args.resume_devices) if shrink else env
                out_r = subprocess.run(
                    launcher_cmd(args, ckdir=ckdir, resume=True), env=r_env,
                    capture_output=True, text=True, timeout=args.timeout)
                if out_r.returncode != 0:
                    scen["errors"].append(
                        f"resume rc={out_r.returncode}\n"
                        f"{out_r.stderr[-4000:]}")
                else:
                    resumed = last_json(out_r.stdout)
                    scen["resumed_from_json"] = resumed.get("resumed_from")
                    if resumed.get("resumed_from") is None:
                        scen["errors"].append(
                            "resumed run did not report resumed_from")
                    if shrink:
                        scen["errors"].extend(compare(
                            resumed, golden_shrunk, EXACT_KEYS))
                        scen["errors"].extend(compare(
                            resumed, golden, CROSS_MESH_EXACT,
                            CROSS_MESH_APPROX))
                    else:
                        scen["errors"].extend(compare(resumed, golden,
                                                      EXACT_KEYS))
                    scen.setdefault("outcome", "resumed")
        if scen["errors"]:
            report["ok"] = False
        report["scenarios"].append(scen)

    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    raise SystemExit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
