"""End-to-end SSumM behavior: budget respected, error–size monotonicity,
determinism, and parity with the faithful sequential oracle."""

import numpy as np
import pytest

from repro.core import SummaryConfig, summarize
from repro.core.ref_numpy import summarize_ref
from repro.graphs import generate
from repro.core import evaluate as ev
from repro.core.types import SummaryResult


def small_graph(seed=0, scale=0.08):
    return generate("ego-facebook", seed=seed, scale=scale)


@pytest.mark.parametrize("k_frac", [0.2, 0.4, 0.6])
def test_budget_respected(k_frac):
    src, dst, v = small_graph()
    res = summarize(src, dst, v, SummaryConfig(T=10, k_frac=k_frac, seed=1))
    assert res.size_bits <= k_frac * res.input_size_bits * (1 + 1e-6)
    assert res.re1 >= 0 and np.isfinite(res.re1)
    assert res.num_supernodes >= 1


def test_error_decreases_with_budget():
    src, dst, v = small_graph()
    res = [summarize(src, dst, v, SummaryConfig(T=10, k_frac=f, seed=1))
           for f in (0.15, 0.3, 0.6)]
    # larger budgets must not be (materially) worse
    assert res[2].re1 <= res[0].re1 * 1.05
    assert res[2].size_bits > res[0].size_bits


def test_deterministic_given_seed():
    src, dst, v = small_graph()
    cfg = SummaryConfig(T=5, k_frac=0.3, seed=7)
    a = summarize(src, dst, v, cfg)
    b = summarize(src, dst, v, cfg)
    np.testing.assert_array_equal(a.node2super, b.node2super)
    assert a.size_bits == b.size_bits


def test_result_metrics_match_dense_bruteforce():
    """The returned summary's (size, RE) match a dense reconstruction."""
    src, dst, v = generate("ego-facebook", seed=3, scale=0.04)
    res = summarize(src, dst, v, SummaryConfig(T=8, k_frac=0.35, seed=3))
    a = ev.dense_adjacency(src, dst, v)
    a_hat = ev.reconstruct_dense(res)
    np.testing.assert_allclose(res.re1, ev.re_p_dense(a, a_hat, 1),
                               rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(res.re2, ev.re_p_dense(a, a_hat, 2),
                               rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(res.size_bits, ev.summary_size_bits_dense(res),
                               rtol=1e-5)


def test_matches_sequential_oracle_trend():
    """Vectorized TPU form ≈ faithful oracle: same budget, comparable RE₁.

    The two searches are differently randomized, so we assert (a) both meet
    the budget and (b) the vectorized RE₁ is within 2× of the oracle's —
    the differential-quality contract of DESIGN.md §3."""
    src, dst, v = small_graph(seed=5, scale=0.05)
    k_frac = 0.3
    vec = summarize(src, dst, v, SummaryConfig(T=10, k_frac=k_frac, seed=5))
    orc = summarize_ref(src, dst, v, k_frac=k_frac, big_t=10, seed=5)
    size_g = vec.input_size_bits
    assert vec.size_bits <= k_frac * size_g * (1 + 1e-6)
    assert orc.size_bits <= k_frac * size_g * (1 + 1e-6)
    assert vec.re1 <= max(orc.re1 * 2.0, orc.re1 + 1e-4)


def test_history_records_progress():
    src, dst, v = small_graph()
    res = summarize(src, dst, v, SummaryConfig(T=6, k_frac=0.25, seed=2))
    assert len(res.history) >= 1
    sizes = [h["size_bits"] for h in res.history]
    assert sizes == sorted(sizes, reverse=True)  # monotone shrinking


def test_history_total_reduction_positive():
    """Rounds that accept merges must report the summed Eq. 20 reduction
    of the accepted pairs — positive bits, not a dead-zero stat."""
    src, dst, v = small_graph()
    res = summarize(src, dst, v, SummaryConfig(T=6, k_frac=0.25, seed=2))
    merging = [h for h in res.history if h["nmerges"] > 0]
    assert merging, "fixture never merged — can't exercise total_reduction"
    for h in merging:
        assert h["total_reduction"] > 0.0, h
    # and rounds with no merges reduce nothing
    for h in res.history:
        if h["nmerges"] == 0:
            assert h["total_reduction"] == 0.0, h
