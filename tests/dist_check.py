"""Subprocess body for distributed tests (needs its own jax init with fake
devices — run via tests/test_distributed.py, never imported by pytest).

Checks, on an 8-device host mesh:
  1. metric parity: with merges disabled (θ=∞), the distributed step's
     size_bits / re1 equal the single-device closed-form evaluation exactly;
  2. a real distributed run merges nodes, respects monotone size shrink,
     and reports zero bucket overflow;
  3. replicated state stays bit-identical across devices;
  4. sparsify parity: the edge-sharded further-sparsification phase
     (psum'd histogram order statistic) produces a drop mask bit-identical
     to single-host further_sparsify and matching post-drop Size(Ḡ)/RE —
     including the ξ == 0 (budget already met) and ξ ≥ |P| (drop
     everything) degenerate branches;
  5. engine parity: SummaryEngine over the unified DistributedBackend
     (while_loop-chunked driver inside the shard_map body, then the
     sparsify finalize) is bit-identical to the explicit per-round
     host loop over the same step — for both driver_chunk=8 and the
     history-equivalent driver_chunk=1.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.core import costs, sparsify
from repro.core.distributed import (
    make_distributed_backend,
    make_distributed_sparsify,
    make_distributed_step,
    make_distributed_step_compact,
    pad_and_shard_edges,
)
from repro.core.engine import SummaryEngine
from repro.core.types import SummaryConfig, init_state, make_graph
from repro.graphs import generate
from repro.launch.mesh import make_host_mesh


def check_step(step, graph, v, e, cfg, mesh, src_p, dst_p, label):
    """Shared assertions: metric parity with merges disabled + progress."""
    state = init_state(v, 0)
    with mesh:
        _, stats0 = step(src_p, dst_p, state, jnp.float32(1e9), jnp.uint32(1))
    assert int(stats0["overflow"]) == 0, (label, "bucket overflow")
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    m = costs.summary_metrics(pt, state, v, e, cbar_mode=cfg.cbar_mode,
                              re_guard=cfg.re_guard)
    np.testing.assert_allclose(float(stats0["size_bits"]),
                               float(m["size_bits"]), rtol=1e-5,
                               err_msg=label)
    np.testing.assert_allclose(float(stats0["re1"]), float(m["re1"]),
                               rtol=1e-5, atol=1e-9, err_msg=label)
    assert int(stats0["nmerges"]) == 0, label
    assert int(stats0["overflow"]) == 0, label

    state = init_state(v, 0)
    sizes = []
    with mesh:
        for t in range(1, 6):
            theta = 1.0 / (1.0 + t)
            state, stats = step(src_p, dst_p, state, jnp.float32(theta),
                                jnp.uint32(t))
            sizes.append(float(stats["size_bits"]))
            assert int(stats["overflow"]) == 0, label
    merged = v - int(jnp.sum(state.size > 0))
    assert merged > 0, f"{label}: never merged"
    assert sizes == sorted(sizes, reverse=True), label
    n2s = np.asarray(state.node2super)
    assert (np.asarray(state.size)[n2s] > 0).all(), label
    return merged, sizes[-1]


def check_sparsify(graph, v, e, cfg, mesh, src_p, dst_p, state, k_bits,
                   label):
    """Edge-sharded sparsify ≡ single-host further_sparsify at ``k_bits``."""
    sp = make_distributed_sparsify(mesh, cfg, v, e, capacity_factor=64.0)
    with mesh:
        stats, pairs = sp(src_p, dst_p, state, jnp.float32(k_bits),
                          jnp.uint32(7))
    assert int(stats["overflow"]) == 0, (label, "sparsify bucket overflow")

    pt = costs.build_pair_table(graph.src, graph.dst, state)
    drop_s, after_s = sparsify.further_sparsify(
        pt, state, v, e, k_bits, cbar_mode=cfg.cbar_mode,
        re_guard=cfg.re_guard, error_p=cfg.error_p)

    # --- drop mask: bit-identical, compared as {(lo, hi) → dropped} ------
    want = {}
    valid = np.asarray(pt.valid) & np.asarray(after_s["keep"] | drop_s)
    for lo, hi, d in zip(np.asarray(pt.lo)[valid], np.asarray(pt.hi)[valid],
                         np.asarray(drop_s)[valid]):
        want[(int(lo), int(hi))] = bool(d)
    got = {}
    mine = np.asarray(pairs["mine"]) & (np.asarray(pairs["keep"])
                                        | np.asarray(pairs["drop"]))
    for lo, hi, d in zip(np.asarray(pairs["lo"])[mine],
                         np.asarray(pairs["hi"])[mine],
                         np.asarray(pairs["drop"])[mine]):
        key = (int(lo), int(hi))
        assert key not in got, (label, "pair owned twice", key)
        got[key] = bool(d)
    assert got == want, (
        f"{label}: drop mask mismatch "
        f"({len(got)} vs {len(want)} pairs, "
        f"{sum(got.get(k) != want.get(k) for k in want)} differ)")

    # --- post-drop metrics: Size(Ḡ) bit-identical, RE to float tolerance -
    assert float(stats["size_bits"]) == float(after_s["size_bits"]), label
    np.testing.assert_allclose(float(stats["re1"]), float(after_s["re1"]),
                               rtol=1e-6, atol=1e-12, err_msg=label)
    np.testing.assert_allclose(float(stats["re2"]), float(after_s["re2"]),
                               rtol=1e-6, atol=1e-12, err_msg=label)
    np.testing.assert_allclose(float(stats["num_superedges"]),
                               float(after_s["num_superedges"]),
                               err_msg=label)
    return int(stats["dropped"])


def check_engine(backend, cfg, mesh, src_p, dst_p, label):
    """SummaryEngine over the backend ≡ the explicit per-round host loop.

    The engine runs the while_loop-chunked ``backend.chunk`` program; the
    reference drives ``backend.step`` (a separate straight-line trace) one
    round at a time with host-python θ and the historical stopping rule —
    every metric, the sparsify payload, and the final partition must be
    bit-identical.
    """
    import copy

    backend = copy.copy(backend)
    backend.cfg = cfg
    k_bits = cfg.target_bits(backend.input_size_bits())
    state = backend.init()
    stats = {}
    t = 0
    with mesh:
        for t in range(1, cfg.T + 1):
            theta = 1.0 / (1.0 + t) if t < cfg.T else 0.0
            state, stats = backend.step(src_p, dst_p, state,
                                        jnp.float32(theta), jnp.uint32(t))
            if float(stats["size_bits"]) <= k_bits:
                break
        ref_sp, _ = backend.sparsify(src_p, dst_p, state,
                                     jnp.float32(k_bits), jnp.uint32(t + 1))

    run = SummaryEngine(backend.bind(src_p, dst_p)).run()
    assert run.iterations_run == t, (label, run.iterations_run, t)
    for k in stats:
        assert float(run.last_stats[k]) == float(stats[k]), (
            label, k, float(run.last_stats[k]), float(stats[k]))
    for k in ref_sp:
        assert float(run.finalize["stats"][k]) == float(ref_sp[k]), (
            label, k, float(run.finalize["stats"][k]), float(ref_sp[k]))
    np.testing.assert_array_equal(np.asarray(run.state.node2super),
                                  np.asarray(state.node2super),
                                  err_msg=label)
    np.testing.assert_array_equal(np.asarray(run.state.size),
                                  np.asarray(state.size), err_msg=label)
    return run


def check_engine_resume(backend, cfg, src_p, dst_p, golden_run):
    """Checkpoint at every chunk boundary, truncate to the first committed
    step, resume — the distributed engine must land bit-identically on the
    golden (uninterrupted) run's metrics, sparsify payload, and partition,
    with the restored state resharded through ``state_sharding()``."""
    import shutil
    import tempfile

    from repro.core.engine import EngineCheckpointer
    from repro.runtime import CheckpointManager

    import copy

    # driver_chunk=2 → several mid-run chunk boundaries to save at; the
    # golden ran chunk=8 (chunking bit-identity is proven above) and
    # driver_chunk is fingerprint-exempt, so the cross-chunk resume is
    # itself part of the contract under test
    bound = copy.copy(backend)
    bound.cfg = dataclasses.replace(cfg, driver_chunk=2)
    bound = bound.bind(src_p, dst_p)
    d = tempfile.mkdtemp(prefix="dist_resume_")
    try:
        ck = EngineCheckpointer(manager=CheckpointManager(d, keep=50),
                                every=1)
        full = SummaryEngine(bound).run(checkpointer=ck)
        assert full.checkpoint_saves >= 1, "no distributed saves happened"
        steps = ck.manager.all_steps()
        for s in steps[1:]:
            shutil.rmtree(os.path.join(d, f"step_{s:010d}"))

        ck2 = EngineCheckpointer(manager=CheckpointManager(d, keep=50),
                                 every=1)
        run = SummaryEngine(bound).run(checkpointer=ck2, resume=True)
        assert run.resumed_from == steps[0], (run.resumed_from, steps)
        assert run.iterations_run == golden_run.iterations_run
        for k in golden_run.last_stats:
            assert float(run.last_stats[k]) == \
                float(golden_run.last_stats[k]), ("resume", k)
        for k in golden_run.finalize["stats"]:
            assert float(run.finalize["stats"][k]) == \
                float(golden_run.finalize["stats"][k]), ("resume final", k)
        np.testing.assert_array_equal(
            np.asarray(run.state.node2super),
            np.asarray(golden_run.state.node2super), err_msg="resume")
        np.testing.assert_array_equal(
            np.asarray(run.state.size),
            np.asarray(golden_run.state.size), err_msg="resume")
        return steps[0]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main():
    assert jax.device_count() == 8
    src, dst, v = generate("ego-facebook", seed=0, scale=0.05)
    graph, _ = make_graph(src, dst, v)
    e = graph.num_edges
    mesh = make_host_mesh((2, 4), ("data", "model"))
    cfg = SummaryConfig(T=5, k_frac=0.3)
    src_p, dst_p = pad_and_shard_edges(np.asarray(graph.src),
                                       np.asarray(graph.dst), mesh)

    step = make_distributed_step(mesh, cfg, v, e)
    merged, final = check_step(step, graph, v, e, cfg, mesh, src_p, dst_p,
                               "hash-owner")

    # group ownership concentrates records (few groups at tiny |V|), so the
    # routing capacity factor is raised here; at web scale the expected
    # per-destination load is E/n_dev² ≪ cap (see dryrun_ssumm)
    step_c = make_distributed_step_compact(mesh, cfg, v, e,
                                           capacity_factor=64.0)
    merged_c, final_c = check_step(step_c, graph, v, e, cfg, mesh, src_p,
                                   dst_p, "compact group-owner")

    # external-groups (regroup_every) path: grouping fn + step must agree
    # with the fused step's metrics when merges are disabled
    from repro.core.distributed import make_grouping_fn

    step_x = make_distributed_step_compact(mesh, cfg, v, e,
                                           capacity_factor=64.0,
                                           lean_sort=True,
                                           external_groups=True)
    gfn = make_grouping_fn(mesh, cfg, v, lean_sort=True)
    state = init_state(v, 0)
    with mesh:
        groups = gfn(src_p, dst_p, state)
        _, stats_x = step_x(src_p, dst_p, state, jnp.float32(1e9),
                            jnp.uint32(1), groups)
    pt = costs.build_pair_table(graph.src, graph.dst, state)
    m = costs.summary_metrics(pt, state, v, e, cbar_mode=cfg.cbar_mode,
                              re_guard=cfg.re_guard)
    np.testing.assert_allclose(float(stats_x["size_bits"]),
                               float(m["size_bits"]), rtol=1e-5,
                               err_msg="external-groups")
    # and a real merge round through the external path
    with mesh:
        state2, stats2 = step_x(src_p, dst_p, state, jnp.float32(0.2),
                                jnp.uint32(1), groups)
    assert int(stats2["nmerges"]) > 0, "external-groups path never merged"

    # ---- distributed further-sparsification parity ----------------------
    # re-run 5 merge rounds to get a realistic post-merge partition
    state = init_state(v, 0)
    with mesh:
        for t in range(1, 6):
            state, stats = step(src_p, dst_p, state,
                                jnp.float32(1.0 / (1.0 + t)), jnp.uint32(t))
    # merge-round stats describe the pre-merge partition; read the current
    # size off the sparsify step itself (ξ=0 probe) before picking budgets
    probe = make_distributed_sparsify(mesh, cfg, v, e, capacity_factor=64.0)
    with mesh:
        pstats, _ = probe(src_p, dst_p, state, jnp.float32(1e12),
                          jnp.uint32(7))
    size_now = float(pstats["size_bits_before"])
    dropped = check_sparsify(graph, v, e, cfg, mesh, src_p, dst_p, state,
                             0.9 * size_now, "sparsify k=0.9·size")
    assert dropped > 0, "sparsify: ξ>0 case never dropped"
    none = check_sparsify(graph, v, e, cfg, mesh, src_p, dst_p, state,
                          2.0 * size_now, "sparsify ξ=0")
    assert none == 0, "sparsify: ξ=0 case dropped superedges"
    check_sparsify(graph, v, e, cfg, mesh, src_p, dst_p, state, 1.0,
                   "sparsify drop-everything")
    cfg2 = SummaryConfig(T=5, k_frac=0.3, error_p=2)
    check_sparsify(graph, v, e, cfg2, mesh, src_p, dst_p, state,
                   0.9 * size_now, "sparsify error_p=2")

    # ---- engine over the unified backend --------------------------------
    # One backend object, cfg swapped host-side: k_bits/ensure_budget are
    # operands / host logic, so the degenerate-budget cases reuse the
    # compiled programs; only driver_chunk=1 retraces (R=1 buffers).
    backend = make_distributed_backend(mesh, cfg, v, e, grouping="compact",
                                       capacity_factor=64.0, lean_sort=True)
    run8 = check_engine(backend, cfg, mesh, src_p, dst_p, "engine chunk=8")
    run1 = check_engine(backend, dataclasses.replace(cfg, driver_chunk=1),
                        mesh, src_p, dst_p, "engine chunk=1")
    hist_keys = ("size_bits", "re1", "nmerges", "num_supernodes")
    assert [{k: r[k] for k in hist_keys} for r in run8.history] == \
           [{k: r[k] for k in hist_keys} for r in run1.history], \
        "chunked driver history differs from sync-every-round driver"
    # ξ=0 (budget met at t=1) and drop-everything finalize branches
    check_engine(backend,
                 dataclasses.replace(cfg, k_frac=None, k_bits=1e12),
                 mesh, src_p, dst_p, "engine xi=0")
    check_engine(backend,
                 dataclasses.replace(cfg, k_frac=None, k_bits=1.0,
                                     ensure_budget=False),
                 mesh, src_p, dst_p, "engine drop-all")

    # ---- checkpoint/resume parity on the 8-device mesh ------------------
    resumed_step = check_engine_resume(backend, cfg, src_p, dst_p, run8)

    print(json.dumps({"ok": True, "merged": merged, "merged_compact": merged_c,
                      "final_size_bits": final,
                      "final_size_bits_compact": final_c,
                      "sparsify_dropped": dropped,
                      "engine_iterations": run8.iterations_run,
                      "resumed_step": resumed_step}))


if __name__ == "__main__":
    main()
