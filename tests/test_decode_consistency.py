"""Decode-vs-forward consistency: stepping the serve path token by token
must reproduce the training forward's logits at every position. This is the
strongest end-to-end check on KV caches, RoPE offsets, recurrent states,
and shared-attention cache sites across all model families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.api import build_model

# all families with a causal decode path (whisper's decode is tested via
# its smoke test; its forward conditions on encoder output so the parity
# harness below doesn't apply verbatim)
ARCHS = [
    "gemma_7b",            # dense GQA + RoPE + GeGLU + embed scaling
    "qwen2_5_14b",         # QKV bias
    "h2o_danube_1_8b",     # sliding-window attention
    "granite_moe_3b_a800m",  # MoE routing in decode
    "xlstm_350m",          # mLSTM/sLSTM recurrent states
    "zamba2_7b",           # Mamba2 SSD + shared attention sites
]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # parity is only defined without capacity drops (the train forward
        # drops different tokens than step-by-step decode); raise capacity
        # so neither side drops
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, n = 2, 12
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, n)), jnp.int32)

    logits_fwd, _ = model.forward(params, {"tokens": tokens}, None, False)

    cache = model.init_cache(b, 32)
    decode = jax.jit(lambda p, c, t, pos: model.serve_step(
        p, {"token": t, "pos": pos, "cache": c}))
    for t in range(n):
        logits_dec, cache = decode(params, cache, tokens[:, t],
                                   jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_fwd[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges from forward at position {t}",
        )
