"""Scheduler-level tests for the batched query-serving layer.

The static-slot scheduler must be a pure transport: answers depend only on
the (kind, u, v) of each request, never on how requests pack into batches
— slot width 1, full width, ragged final batch, interleaved submissions
all agree with the single-query numpy reference."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import SummaryConfig, summarize
from repro.core import queries as Q
from repro.core.queries_jax import (
    KIND_ADJACENCY,
    KIND_DEGREE,
    KIND_PAGERANK,
    KIND_TRIANGLE,
    QueryEngine,
)
from repro.graphs import generate
from repro.launch import query_serve
from repro.launch.query_serve import QueryRequest, QueryServer, random_workload


@pytest.fixture(scope="module")
def served():
    src, dst, v = generate("ego-facebook", seed=2, scale=0.05)
    res = summarize(src, dst, v, SummaryConfig(T=6, k_frac=0.4, seed=2),
                    collect_history=False)
    return res, QueryEngine(res), v


def _drain(server, reqs):
    for r in reqs:
        server.submit(dataclasses.replace(r))
    steps = 0
    while server.step():
        steps += 1
    return {r.rid: r.answer for r in server.done}, steps


def test_batching_invariance(served):
    """Same 37 requests through slot widths {1, 8, 16}: 37 is ragged for
    both batched widths (final batches of 5 and 7), yet every answer is
    identical — and equals the numpy single-query reference."""
    res, engine, v = served
    rng = np.random.default_rng(0)
    reqs = random_workload(rng, v, 37, [KIND_DEGREE, KIND_ADJACENCY,
                                        KIND_PAGERANK, KIND_TRIANGLE])
    answers = {}
    for slots in (1, 8, 16):
        answers[slots], _ = _drain(QueryServer(engine, slots=slots), reqs)
    assert answers[1] == answers[8] == answers[16]

    pr = Q.pagerank_summary(res)
    tri = Q.triangle_density(res)
    for r in reqs:
        if r.kind == KIND_DEGREE:
            want = Q.expected_degree(res, r.u)
        elif r.kind == KIND_ADJACENCY:
            want = Q.adjacency_weight(res, r.u, r.v)
        elif r.kind == KIND_PAGERANK:
            want = pr[r.u]
        else:
            want = tri
        np.testing.assert_allclose(answers[1][r.rid], want,
                                   rtol=1e-9, atol=1e-12)


def test_slot_refill_and_step_count(served):
    """11 requests through 4 slots: exactly ceil(11/4)=3 steps, every
    request answered once, queue fully drained."""
    _, engine, v = served
    rng = np.random.default_rng(1)
    reqs = random_workload(rng, v, 11, [KIND_DEGREE, KIND_ADJACENCY])
    server = QueryServer(engine, slots=4)
    answers, steps = _drain(server, reqs)
    assert steps == 3
    assert sorted(answers) == list(range(11))
    assert not server.queue
    # latency bookkeeping is populated for every request
    assert all(r.t_done >= r.t_submit > 0 for r in server.done)


def test_submit_between_steps(served):
    """Requests arriving while earlier batches are in flight are picked up
    by later steps (continuous refill), with unchanged answers."""
    _, engine, v = served
    rng = np.random.default_rng(2)
    reqs = random_workload(rng, v, 12, [KIND_DEGREE, KIND_PAGERANK])
    base, _ = _drain(QueryServer(engine, slots=4), reqs)

    server = QueryServer(engine, slots=4)
    for r in reqs[:4]:
        server.submit(dataclasses.replace(r))
    assert server.step()
    for r in reqs[4:]:
        server.submit(dataclasses.replace(r))
    while server.step():
        pass
    assert {r.rid: r.answer for r in server.done} == base


def test_driver_smoke(capsys, tmp_path, monkeypatch):
    """launch.query_serve main(): serves the workload and reports the
    latency/throughput JSON contract (p50/p99/QPS, per-kind counts)."""
    rec = query_serve.main([
        "--dataset", "ego-facebook", "--scale", "0.05", "--T", "4",
        "--k-frac", "0.4", "--requests", "40", "--batch", "16",
        "--queries", "degree,adjacency,pagerank,triangle", "--seed", "2"])
    out = capsys.readouterr().out
    assert json.loads(out) == rec
    assert rec["requests"] == 40
    assert sum(rec["queries"].values()) == 40
    assert set(rec["queries"]) == {"degree", "adjacency", "pagerank",
                                   "triangle"}
    assert rec["qps"] > 0
    assert 0 < rec["p50_latency_s"] <= rec["p99_latency_s"]
    assert rec["mode"] == "local"


def test_driver_rejects_unknown_kind():
    with pytest.raises(SystemExit):
        query_serve.main(["--dataset", "ego-facebook", "--scale", "0.05",
                          "--queries", "degree,bogus"])


def test_request_defaults():
    r = QueryRequest(rid=0, kind=KIND_DEGREE)
    assert r.u == 0 and r.v == 0 and r.answer is None
