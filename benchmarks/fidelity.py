"""Fidelity: the TPU-vectorized SSumM vs the paper-faithful sequential
oracle (core/ref_numpy.py) on the same graphs and budgets.

This is the paper-reproduction baseline of §Perf: the oracle implements
Alg. 1/2 verbatim (sequential within-group merging, log₂|C| pair sampling,
skip counters); the vectorized form is the beyond-paper TPU adaptation.
Reported per (dataset, k): both sizes (must both be ≤ k), both RE₁, the
RE ratio, and wall times.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, save_artifact
from repro.core import SummaryConfig, summarize
from repro.core.ref_numpy import summarize_ref
from repro.graphs import generate


def run(datasets=("ego-facebook", "dblp"), scale=0.1, k_fracs=(0.3, 0.5),
        T=20, seed=0) -> list[dict]:
    rows = []
    for ds in datasets:
        src, dst, v = generate(ds, seed=seed, scale=scale)
        for k in k_fracs:
            t0 = time.perf_counter()
            vec = summarize(src, dst, v, SummaryConfig(T=T, k_frac=k,
                                                       seed=seed))
            t_vec = time.perf_counter() - t0
            t0 = time.perf_counter()
            orc = summarize_ref(src, dst, v, k_frac=k, big_t=T, seed=seed)
            t_orc = time.perf_counter() - t0
            size_g = vec.input_size_bits
            r = {
                "bench": "fidelity", "dataset": ds, "V": v, "E": len(src),
                "k_frac": k,
                "oracle_rel_size": orc.size_bits / size_g,
                "vector_rel_size": vec.size_bits / size_g,
                "oracle_re1": orc.re1,
                "vector_re1": vec.re1,
                "re1_ratio_vec_over_oracle":
                    vec.re1 / max(orc.re1, 1e-12),
                "oracle_wall_s": t_orc,
                "vector_wall_s": t_vec,
                "budget_ok": bool(vec.size_bits <= k * size_g * (1 + 1e-6)
                                  and orc.size_bits <= k * size_g * (1 + 1e-6)),
            }
            rows.append(r)
            emit(r)
    save_artifact("fidelity", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", nargs="+", default=["ego-facebook", "dblp"])
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--k-fracs", nargs="+", type=float, default=[0.3, 0.5])
    ap.add_argument("--T", type=int, default=20)
    args = ap.parse_args()
    run(args.datasets, args.scale, tuple(args.k_fracs), args.T)


if __name__ == "__main__":
    main()
