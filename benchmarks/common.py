"""Shared benchmark plumbing: method registry, CSV emission, artifacts."""

from __future__ import annotations

import json
import os
import time

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def emit(row: dict) -> None:
    """One CSV-ish line per measurement (stable key order)."""
    print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)


def save_artifact(name: str, payload) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def run_ssumm(src, dst, v, k_frac: float, T: int = 20, seed: int = 0,
              group_size: int = 32):
    from repro.core import SummaryConfig, summarize

    t0 = time.perf_counter()
    res = summarize(src, dst, v, SummaryConfig(
        T=T, k_frac=k_frac, seed=seed, group_size=group_size))
    return {
        "method": "ssumm",
        "target": k_frac,
        "rel_size": res.size_bits / res.input_size_bits,
        "re1": res.re1,
        "re2": res.re2,
        "supernodes": res.num_supernodes,
        "wall_s": time.perf_counter() - t0,
    }


def run_baseline(name: str, src, dst, v, frac: float, seed: int = 0):
    from repro import baselines as B

    fn = {
        "kgs": B.summarize_kgs,
        "s2l": B.summarize_s2l,
        "saa_gs": lambda *a, **k: B.summarize_saa_gs(*a, **k),
        "saa_gs_linear": lambda *a, **k: B.summarize_saa_gs(
            *a, linear_sample=True, **k
        ),
    }[name]
    res = fn(src, dst, v, target_frac=frac, seed=seed)
    return {
        "method": name,
        "target": frac,
        "rel_size": res.size_bits / res.input_size_bits,
        "re1": res.re1,
        "re2": res.re2,
        "supernodes": res.num_supernodes,
        "wall_s": res.wall_s,
    }


def quality(rows: list[dict]) -> None:
    """Fig. 5's quality metric: distance to the per-dataset ideal point after
    min-max normalizing size and RE₁ over all methods."""
    sizes = np.array([r["rel_size"] for r in rows])
    errs = np.array([r["re1"] for r in rows])

    def norm(x):
        lo, hi = x.min(), x.max()
        return (x - lo) / (hi - lo) if hi > lo else np.zeros_like(x)

    q = np.sqrt(norm(sizes) ** 2 + norm(errs) ** 2)
    for r, qi in zip(rows, q):
        r["quality"] = float(qi)
