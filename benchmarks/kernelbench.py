"""Kernel micro-benchmark: merge-gain scoring throughput.

Reports wall time and achieved pair-score rate per kernel-registry backend:
``"ref"`` (the jitted jnp oracle — the XLA path a CPU host runs) and
``"pallas-interpret"`` (functional check only — interpret timing is
meaningless for TPU; the BlockSpec/VMEM sizing notes live in
kernels/merge_gain.py). On a real accelerator add ``"pallas"`` via
``--backends``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_artifact
from repro.kernels import ops as kops


def make_operands(g, c, u, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.poisson(0.5, size=(g, c, u)).astype(np.float32)
    n = rng.integers(1, 50, size=(g, c)).astype(np.float32)
    s = rng.poisson(0.3, size=(g, c)).astype(np.float32)
    n_u = rng.integers(1, 50, size=(g, u)).astype(np.float32)
    cidx = rng.integers(0, u, size=(g, c)).astype(np.int32)
    w = rng.poisson(0.2, size=(g, c, c)).astype(np.float32)
    w = np.maximum(w, np.swapaxes(w, 1, 2))
    t = (m.sum(-1) * 10.0 + 30.0).astype(np.float32)
    args = [jnp.asarray(x) for x in (m, n, s, t, n_u, cidx, w)]
    return args + [jnp.float32(60.0), jnp.float32(20.0)]


def bench(fn, args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(sizes=((256, 32, 128), (64, 64, 256)), iters=5,
        backends=("ref", "pallas-interpret")) -> list[dict]:
    rows = []
    for g, c, u in sizes:
        args = make_operands(g, c, u)
        pairs = g * c * c
        for backend in backends:
            # interpret mode runs a host callback per grid step — time a
            # single call, the number is a functional-path marker only
            n_iters = iters if backend == "ref" else 1
            t = bench(
                lambda *a, _b=backend: kops.merge_gain(*a, backend=_b),
                args, n_iters)
            r = {"bench": "kernel_merge_gain", "G": g, "C": c, "U": u,
                 "impl": backend, "wall_s": t,
                 "pair_scores_per_s": pairs / t,
                 "flops_est": pairs * u * 12.0}
            rows.append(r)
            emit(r)
    save_artifact("kernelbench", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--backends", nargs="+",
                    default=["ref", "pallas-interpret"],
                    choices=list(kops.KERNEL_BACKENDS))
    args = ap.parse_args()
    run(iters=args.iters, backends=tuple(args.backends))


if __name__ == "__main__":
    main()
