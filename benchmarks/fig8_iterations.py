"""Fig. 8: effect of the iteration count T — RE₁ vs t for several targets.

The per-iteration history that ``summarize`` already records provides the
whole curve in one run per target size; the paper's claim to check is
convergence within T=20 for every target.

The artifact also records the engine's driver overhead (DESIGN.md §12):
the same run timed with ``driver_chunk=1`` (a host sync every round — the
historical driver) vs the chunked ``lax.while_loop`` driver, reported as
per-round wall seconds. Metrics are bit-identical between the two
(tests/test_engine.py), so the delta is pure dispatch/sync overhead.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, save_artifact
from repro.core import SummaryConfig, summarize
from repro.graphs import generate


def _timed_run(src, dst, v, cfg):
    res = summarize(src, dst, v, cfg)  # warm the jit caches for this cfg
    t0 = time.perf_counter()
    res = summarize(src, dst, v, cfg, collect_history=False)
    return res, time.perf_counter() - t0


def run(dataset="amazon0601", scale=0.02, targets=(0.3, 0.5, 0.8), T=20,
        seed=0) -> list[dict]:
    src, dst, v = generate(dataset, seed=seed, scale=scale)
    rows = []
    for k_frac in targets:
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        res = summarize(src, dst, v, cfg)
        for h in res.history:
            r = {"bench": "fig8", "dataset": dataset, "target": k_frac,
                 "t": h["t"], "re1": h["re1"],
                 "size_bits": h["size_bits"],
                 "supernodes": h["num_supernodes"]}
            rows.append(r)
            emit(r)
        rows.append({"bench": "fig8_final", "target": k_frac,
                     "iterations_run": res.iterations_run,
                     "re1": res.re1,
                     "rel_size": res.size_bits / res.input_size_bits})
        emit(rows[-1])

        # driver overhead: sync-every-round (R=1) vs the chunked driver
        res_1, wall_1 = _timed_run(
            src, dst, v, SummaryConfig(T=T, k_frac=k_frac, seed=seed,
                                       driver_chunk=1))
        res_c, wall_c = _timed_run(src, dst, v, cfg)
        n = max(res_c.iterations_run, 1)
        assert res_1.size_bits == res_c.size_bits  # same search, same metrics
        rows.append({"bench": "fig8_driver", "target": k_frac,
                     "driver_chunk": cfg.driver_chunk,
                     "iterations_run": res_c.iterations_run,
                     "wall_s_chunked": wall_c,
                     "wall_s_sync_every_round": wall_1,
                     "per_round_s_chunked": wall_c / n,
                     "per_round_s_sync_every_round": wall_1 / n,
                     "per_round_driver_overhead_s": (wall_1 - wall_c) / n})
        emit(rows[-1])
    save_artifact("fig8_iterations", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="amazon0601")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--targets", nargs="+", type=float, default=[0.3, 0.5, 0.8])
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.dataset, args.scale, tuple(args.targets), args.T, args.seed)


if __name__ == "__main__":
    main()
