"""Table 2: the dataset registry (offline synthetic stand-ins) with realized
|V|, |E| and Size(G) per Eq. (3). Web-scale rows are listed but materialized
only at --full (they exist for the dry-run / distributed path)."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.graphs import DATASETS, generate


def run(scale=0.05, materialize_max_e=5_000_000) -> list[dict]:
    rows = []
    for name, spec in DATASETS.items():
        row = {"bench": "table2", "name": name, "short": spec.short,
               "V_spec": spec.v, "E_spec": spec.e_target, "kind": spec.kind,
               "size_g_bits_spec": 2.0 * spec.e_target * np.log2(max(spec.v, 2))}
        if spec.e_target * scale <= materialize_max_e:
            src, dst, v = generate(name, scale=scale)
            row.update({"scale": scale, "V": v, "E": len(src),
                        "size_g_bits": 2.0 * len(src) * np.log2(max(v, 2))})
        else:
            row.update({"scale": 0, "V": 0, "E": 0, "size_g_bits": 0,
                        "note": "dry-run only"})
        rows.append(row)
        emit(row)
    save_artifact("table2_datasets", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.05)
    args = ap.parse_args()
    run(args.scale)


if __name__ == "__main__":
    main()
