"""Table 2: the dataset registry with realized |V|, |E| and Size(G).

Each row resolves real-data-first (DESIGN.md §10): a SNAP file under
``$SSUMM_DATA_DIR`` → its binary CSR cache → the offline synthetic
stand-in. The ``source`` column labels which one backed the row
(``real|cache|synthetic|spec``). Whenever a graph is actually loaded —
from a real file *or* a stand-in — ``size_g_bits`` is Eq. (3) on the
*realized* |V|, |E|; only never-materialized web-scale rows fall back to
the spec values (``source="spec"``, dry-run only).
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, save_artifact
from repro.core import costs
from repro.graphs import DATASETS, load_graph
from repro.graphs.io import cache_is_fresh, default_cache_dir, find_real_file


def _resolution(name: str) -> str:
    """Where ``load_graph(name)`` would read from, without loading."""
    path = find_real_file(name)
    if path is not None:
        return "cache" if cache_is_fresh(default_cache_dir(path), path) \
            else "real"
    return "synthetic"


def run(scale=0.05, materialize_max_e=5_000_000) -> list[dict]:
    rows = []
    for name, spec in DATASETS.items():
        row = {"bench": "table2", "name": name, "short": spec.short,
               "V_spec": spec.v, "E_spec": spec.e_target, "kind": spec.kind,
               "size_g_bits_spec":
                   costs.input_size_bits(spec.v, spec.e_target)}
        res = _resolution(name)
        # real files are full-size by definition; synthetic stand-ins only
        # materialize when the scaled |E| fits the budget
        if res != "synthetic" or spec.e_target * scale <= materialize_max_e:
            g = load_graph(name, scale=scale)
            row.update({
                "source": g.source,
                "scale": scale if g.source == "synthetic" else 1.0,
                "V": g.num_nodes, "E": g.num_edges,
                "size_g_bits":
                    costs.input_size_bits(g.num_nodes, g.num_edges),
            })
        else:
            # dry-run only: never materialized, so Eq. (3) on the spec
            # values is all there is — labeled, not silently mixed in
            row.update({"source": "spec", "scale": 0, "V": spec.v,
                        "E": spec.e_target,
                        "size_g_bits":
                            costs.input_size_bits(spec.v, spec.e_target),
                        "note": "dry-run only"})
        rows.append(row)
        emit(row)
    save_artifact("table2_datasets", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.05)
    args = ap.parse_args()
    run(args.scale)


if __name__ == "__main__":
    main()
