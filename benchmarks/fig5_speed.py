"""Fig. 5: speed vs summary quality.

One point per method per dataset at the paper's representative setting
(target 30%); quality = normalized Euclidean distance to the ideal
(size, RE₁) corner, computed over all methods on the same dataset.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, quality, run_baseline, run_ssumm, save_artifact
from repro.graphs import generate


def run(datasets=("ego-facebook",), scale=0.25, frac=0.3, seed=0,
        methods=("ssumm", "kgs", "s2l", "saa_gs", "saa_gs_linear")) -> list[dict]:
    rows = []
    for ds in datasets:
        src, dst, v = generate(ds, seed=seed, scale=scale)
        per_ds = []
        for m in methods:
            if m == "saa_gs_linear" and len(src) > 10_000:
                # reproduces the paper's o.o.t. behavior: the linear-sample
                # variant does not scale past small graphs
                emit({"bench": "fig5", "dataset": ds, "method": m,
                      "status": "o.o.t.(skipped)"})
                continue
            if m == "ssumm":
                r = run_ssumm(src, dst, v, k_frac=frac, seed=seed)
            else:
                r = run_baseline(m, src, dst, v, frac, seed=seed)
            r.update({"bench": "fig5", "dataset": ds, "V": v, "E": len(src)})
            per_ds.append(r)
        quality(per_ds)
        for r in per_ds:
            emit(r)
        rows.extend(per_ds)
    save_artifact("fig5_speed", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", nargs="+", default=["ego-facebook", "dblp"])
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--frac", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.datasets, args.scale, args.frac, args.seed)


if __name__ == "__main__":
    main()
