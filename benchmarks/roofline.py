"""§Roofline: assemble the per-(arch × shape × mesh) table from the dry-run
artifacts (launch/dryrun.py JSONs). Emits one row per cell: the three terms
in seconds, the dominant bottleneck, MODEL_FLOPS/HLO ratio, and the
roofline fraction. ``--perf`` additionally lists tagged perf-iteration
variants side by side with their baselines (§Perf before/after)."""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_artifact

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(dryrun_dir: str) -> list[dict]:
    rows = []
    if not os.path.isdir(dryrun_dir):
        return rows
    for fn in sorted(os.listdir(dryrun_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(dryrun_dir, fn)) as f:
                rows.append(json.load(f))
    return rows


def run(dryrun_dir: str = DEFAULT_DIR, include_tags: bool = False) -> list[dict]:
    out = []
    for r in load(dryrun_dir):
        if r.get("tag") and not include_tags:
            continue
        row = {"bench": "roofline", "arch": r["arch"], "shape": r["shape"],
               "mesh": r["mesh"], "tag": r.get("tag", ""),
               "status": r["status"]}
        if r["status"] == "ok":
            rf = r["roofline"]
            row.update({
                "t_compute_s": rf["t_compute"],
                "t_memory_s": rf["t_memory"],
                "t_collective_s": rf["t_collective"],
                "bottleneck": rf["bottleneck"],
                "useful_ratio": rf["useful_ratio"],
                "roofline_frac": rf["roofline_fraction"],
                "hbm_args_gib_per_dev": r["memory"]["argument_bytes"] / 2**30,
                "coll_bytes_per_dev": r["collectives"]["total"],
            })
        else:
            row["error"] = r.get("error", "")[:120]
        out.append(row)
        emit(row)
    save_artifact("roofline", out)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--perf", action="store_true", help="include tagged variants")
    args = ap.parse_args()
    run(args.dir, include_tags=args.perf)


if __name__ == "__main__":
    main()
