"""Fig. 6 / Thm. 3.4: linear scalability — SSumM runtime vs |E|.

Subsamples of the amazon0601/skitter stand-ins at geometric |E| steps; jit
compile time is excluded (one warm-up run at the smallest size, then every
size reuses the same compiled iteration because shapes enter the jit cache
per size — we therefore report the *second* run per size). A least-squares
fit of time vs |E| reports R² against the linear model.

``--distributed`` runs the edge-sharded pipeline instead (merge rounds +
the distributed sparsify tail, DESIGN.md §7) over ``--devices`` placeholder
host devices and reports the sparsify phase's wall time separately — the
scalability story the single-host mode cannot exercise.

``--edge-list PATH [PATH ...]`` times the *real-data* pipeline stages
separately per file: cold streaming ingest (text → CSR cache, forced
re-parse), warm cache load (mmap, 0 bytes parsed), and the summarize
itself — so ingest scaling is visible next to Thm. 3.4's merge-loop
scaling instead of being folded into one number (DESIGN.md §10).

``--distributed --edge-list`` combines them: each file's CSR cache is fed
straight onto the mesh (``repro.graphs.feed.shard_edges_from_cache``,
DESIGN.md §11) and the edge-sharded pipeline runs out-of-core — the feed,
merge-loop, and sparsify-tail times are reported per file along with the
feed's staging accounting (host staging = one shard, never |E|).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.graphs import generate


def run(dataset="amazon0601", scales=(0.01, 0.02, 0.04, 0.08), T=5,
        seed=0, k_frac=0.3) -> list[dict]:
    from repro.core import SummaryConfig, summarize

    rows = []
    for sc in scales:
        src, dst, v = generate(dataset, seed=seed, scale=sc)
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        summarize(src, dst, v, cfg)  # warm-up: jit compile for this size
        t0 = time.perf_counter()
        res = summarize(src, dst, v, cfg)
        dt = time.perf_counter() - t0
        r = {"bench": "fig6", "dataset": dataset, "scale": sc, "V": v,
             "E": len(src), "T": T, "wall_s": dt,
             "rel_size": res.size_bits / res.input_size_bits, "re1": res.re1}
        rows.append(r)
        emit(r)
    es = np.array([r["E"] for r in rows], float)
    ts = np.array([r["wall_s"] for r in rows], float)
    k = float((es * ts).sum() / (es * es).sum())  # through-origin linear fit
    ss_res = float(((ts - k * es) ** 2).sum())
    ss_tot = float(((ts - ts.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    fit = {"bench": "fig6_fit", "dataset": dataset, "slope_s_per_edge": k,
           "r2_linear": r2}
    emit(fit)
    rows.append(fit)
    save_artifact("fig6_scalability", rows)
    return rows


def run_distributed(dataset="amazon0601", scales=(0.01, 0.02), T=5, seed=0,
                    k_frac=0.3, devices=8) -> list[dict]:
    """Edge-sharded pipeline per scale: merge rounds + the distributed
    sparsify tail (psum'd histogram order statistic). The sparsify phase
    is timed separately so its scaling is visible next to the merge loop's.
    """
    from repro.core import SummaryConfig
    from repro.core.types import make_graph
    from repro.launch.mesh import make_host_mesh
    from repro.launch.summarize import (
        build_distributed_pipeline,
        run_distributed as run_dist_pipeline,
    )

    from repro.graphs.feed import ShardFeeder, shard_edges

    mesh = make_host_mesh((devices,), ("data",))
    rows = []
    # one feeder shared across scales — it allocates a fresh buffer per
    # shard (in-place reuse would corrupt earlier feeds under PJRT CPU
    # zero-copy adoption; see feed.ShardFeeder) and accumulates the
    # sweep-wide staging high-water mark
    feeder = ShardFeeder()
    for sc in scales:
        src, dst, v = generate(dataset, seed=seed, scale=sc)
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        graph, _ = make_graph(src, dst, v)
        # one feed + one jitted pipeline per size, both reused so the
        # timed run hits the jit cache (fresh closures would retrace +
        # recompile every call) and isn't charged for the host→device feed
        t0 = time.perf_counter()
        shards = shard_edges(np.asarray(graph.src), np.asarray(graph.dst),
                             mesh, feeder=feeder)
        t_feed = time.perf_counter() - t0
        pipe = build_distributed_pipeline(mesh, cfg, v, graph.num_edges)
        run_dist_pipeline(None, None, v, cfg, mesh, pipeline=pipe,
                          shards=shards)  # warm-up
        t0 = time.perf_counter()
        _state, stats, size_g = run_dist_pipeline(None, None, v, cfg, mesh,
                                                  pipeline=pipe,
                                                  shards=shards)
        dt = time.perf_counter() - t0
        r = {"bench": "fig6_distributed", "dataset": dataset, "scale": sc,
             "V": v, "E": len(src), "T": T, "devices": devices,
             "wall_s": dt, "feed_wall_s": t_feed,
             "sparsify_wall_s": stats["sparsify_wall_s"],
             "rel_size": stats["size_bits"] / size_g, "re1": stats["re1"],
             "superedges_dropped": stats["dropped"]}
        rows.append(r)
        emit(r)
    save_artifact("fig6_scalability_distributed", rows)
    return rows


def run_edge_list(paths, T=5, seed=0, k_frac=0.3,
                  chunk_edges=None) -> list[dict]:
    """Per file: timed cold ingest, timed warm cache load, timed summarize.

    The cold pass forces a re-parse (``refresh=True``) so the text→CSR
    stage is actually measured even when a fresh cache exists; the warm
    pass must report ``ingest_bytes_parsed == 0``.
    """
    from repro.core import SummaryConfig, summarize
    from repro.graphs import load_graph

    rows = []
    for path in paths:
        t0 = time.perf_counter()
        g = load_graph(path, chunk_edges=chunk_edges, refresh=True)
        t_ingest = time.perf_counter() - t0
        t0 = time.perf_counter()
        g = load_graph(path, chunk_edges=chunk_edges)
        t_load = time.perf_counter() - t0
        assert g.stats.bytes_parsed == 0, "warm load re-parsed the text file"
        src, dst = np.asarray(g.src), np.asarray(g.dst)
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        summarize(src, dst, g.num_nodes, cfg)  # warm-up: jit compile
        t0 = time.perf_counter()
        res = summarize(src, dst, g.num_nodes, cfg)
        t_sum = time.perf_counter() - t0
        r = {"bench": "fig6_edge_list", "path": path, "V": g.num_nodes,
             "E": g.num_edges, "T": T, "ingest_wall_s": t_ingest,
             "cache_load_wall_s": t_load, "summarize_wall_s": t_sum,
             "ingest_edges_per_s": g.num_edges / max(t_ingest, 1e-9),
             "rel_size": res.size_bits / res.input_size_bits,
             "re1": res.re1}
        rows.append(r)
        emit(r)
    save_artifact("fig6_edge_list", rows)
    return rows


def run_distributed_edge_list(paths, T=5, seed=0, k_frac=0.3,
                              chunk_edges=None, devices=8) -> list[dict]:
    """Out-of-core per file: CSR cache → per-shard feed → edge-sharded run.

    The cache's mmap'd columns go straight onto the mesh
    (``shard_edges_from_cache``, DESIGN.md §11) — the full edge list is
    never materialized on the host, and the row records the feed's staging
    high-water mark next to its wall time so the memory story is auditable
    alongside the scaling one.
    """
    from repro.core import SummaryConfig
    from repro.graphs import load_graph
    from repro.graphs.feed import ShardFeeder, shard_edges_from_cache
    from repro.launch.mesh import make_host_mesh
    from repro.launch.summarize import (
        build_distributed_pipeline,
        run_distributed as run_dist_pipeline,
    )

    mesh = make_host_mesh((devices,), ("data",))
    feeder = ShardFeeder()
    rows = []
    for path in paths:
        g = load_graph(path, chunk_edges=chunk_edges)  # ingest iff no cache
        assert g.cache_dir is not None, f"{path}: no CSR cache to feed from"
        v, e, cache_dir = g.num_nodes, g.num_edges, g.cache_dir
        del g  # drop the mmap handles; the feed reopens its own
        t0 = time.perf_counter()
        shards = shard_edges_from_cache(cache_dir, mesh, feeder=feeder)
        t_feed = time.perf_counter() - t0
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        pipe = build_distributed_pipeline(mesh, cfg, v, e)
        run_dist_pipeline(None, None, v, cfg, mesh, pipeline=pipe,
                          shards=shards)  # warm-up
        t0 = time.perf_counter()
        _state, stats, size_g = run_dist_pipeline(None, None, v, cfg, mesh,
                                                  pipeline=pipe,
                                                  shards=shards)
        dt = time.perf_counter() - t0
        fs = shards.stats
        r = {"bench": "fig6_dist_edge_list", "path": path, "V": v, "E": e,
             "T": T, "devices": devices, "wall_s": dt, "feed_wall_s": t_feed,
             "sparsify_wall_s": stats["sparsify_wall_s"],
             "feed_path": fs.path, "feed_shard_rows": fs.shard_rows,
             "feed_peak_staging_bytes": fs.peak_staging_bytes,
             "feed_bytes_copied": fs.bytes_copied,
             "rel_size": stats["size_bits"] / size_g, "re1": stats["re1"],
             "superedges_dropped": stats["dropped"]}
        rows.append(r)
        emit(r)
    save_artifact("fig6_dist_edge_list", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="amazon0601")
    ap.add_argument("--scales", nargs="+", type=float,
                    default=[0.01, 0.02, 0.04, 0.08])
    ap.add_argument("--T", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="edge-sharded pipeline incl. the sparsify tail")
    ap.add_argument("--devices", type=int, default=8,
                    help="placeholder host devices for --distributed")
    ap.add_argument("--edge-list", nargs="+", default=None, metavar="PATH",
                    help="time ingest/load/summarize per SNAP file")
    ap.add_argument("--chunk-edges", type=int, default=None)
    args = ap.parse_args()
    if args.distributed:
        # must precede the first jax backend init (device count is locked
        # then); harmless if the user already exported their own flags
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")
    if args.edge_list and args.distributed:
        run_distributed_edge_list(args.edge_list, T=args.T, seed=args.seed,
                                  chunk_edges=args.chunk_edges,
                                  devices=args.devices)
    elif args.edge_list:
        run_edge_list(args.edge_list, T=args.T, seed=args.seed,
                      chunk_edges=args.chunk_edges)
    elif args.distributed:
        run_distributed(args.dataset, tuple(args.scales), args.T, args.seed,
                        devices=args.devices)
    else:
        run(args.dataset, tuple(args.scales), args.T, args.seed)


if __name__ == "__main__":
    main()
