"""Fig. 6 / Thm. 3.4: linear scalability — SSumM runtime vs |E|.

Subsamples of the amazon0601/skitter stand-ins at geometric |E| steps; jit
compile time is excluded (one warm-up run at the smallest size, then every
size reuses the same compiled iteration because shapes enter the jit cache
per size — we therefore report the *second* run per size). A least-squares
fit of time vs |E| reports R² against the linear model.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.core import SummaryConfig, summarize
from repro.graphs import generate


def run(dataset="amazon0601", scales=(0.01, 0.02, 0.04, 0.08), T=5,
        seed=0, k_frac=0.3) -> list[dict]:
    rows = []
    for sc in scales:
        src, dst, v = generate(dataset, seed=seed, scale=sc)
        cfg = SummaryConfig(T=T, k_frac=k_frac, seed=seed)
        summarize(src, dst, v, cfg)  # warm-up: jit compile for this size
        t0 = time.perf_counter()
        res = summarize(src, dst, v, cfg)
        dt = time.perf_counter() - t0
        r = {"bench": "fig6", "dataset": dataset, "scale": sc, "V": v,
             "E": len(src), "T": T, "wall_s": dt,
             "rel_size": res.size_bits / res.input_size_bits, "re1": res.re1}
        rows.append(r)
        emit(r)
    es = np.array([r["E"] for r in rows], float)
    ts = np.array([r["wall_s"] for r in rows], float)
    k = float((es * ts).sum() / (es * es).sum())  # through-origin linear fit
    ss_res = float(((ts - k * es) ** 2).sum())
    ss_tot = float(((ts - ts.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    fit = {"bench": "fig6_fit", "dataset": dataset, "slope_s_per_edge": k,
           "r2_linear": r2}
    emit(fit)
    rows.append(fit)
    save_artifact("fig6_scalability", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="amazon0601")
    ap.add_argument("--scales", nargs="+", type=float,
                    default=[0.01, 0.02, 0.04, 0.08])
    ap.add_argument("--T", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.dataset, tuple(args.scales), args.T, args.seed)


if __name__ == "__main__":
    main()
