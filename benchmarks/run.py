"""Benchmark orchestrator: one suite per paper table/figure + the roofline
table from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run            # standard suite
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
    PYTHONPATH=src python -m benchmarks.run --only fig4 fig6

Emits ``key=value`` CSV rows (stdout) and JSON artifacts under
``artifacts/bench/``. Sized for the 1-core CPU container; every suite
accepts larger settings via its own __main__ for real runs.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="+", default=None,
                    help="subset: table2 fig4 fig5 fig6 fig8 kernels roofline")
    args = ap.parse_args()

    quick = args.quick
    suites = args.only or ["table2", "fig4", "fig5", "fig6", "fig8",
                           "fidelity", "kernels", "roofline"]
    t_start = time.time()

    if "table2" in suites:
        from benchmarks import table2_datasets
        print("# --- Table 2: datasets -------------------------------------")
        table2_datasets.run(scale=0.02 if quick else 0.05)

    # NOTE on full-mode sizes: k-Gs/SAA-Gs are O(|V|²·deg) sequential
    # baselines (the paper's own scalability point) — multi-method figures
    # therefore run on small-|V| graphs; SSumM-only figures use larger ones.
    if "fig4" in suites:
        from benchmarks import fig4_compactness
        print("# --- Fig. 4/7: compactness & accuracy ----------------------")
        fig4_compactness.run(
            datasets=("ego-facebook",) if quick else ("ego-facebook",),
            scale=0.1 if quick else 0.25,
            fracs=(0.2, 0.4) if quick else (0.1, 0.2, 0.3, 0.4, 0.5, 0.6),
            methods=("ssumm", "kgs", "s2l", "saa_gs"),
        )
        if not quick:  # second dataset at baseline-feasible |V|
            fig4_compactness.run(
                datasets=("dblp",), scale=0.01,
                fracs=(0.2, 0.4, 0.6),
                methods=("ssumm", "kgs", "s2l", "saa_gs"),
            )

    if "fig5" in suites:
        from benchmarks import fig5_speed
        print("# --- Fig. 5: speed vs quality ------------------------------")
        fig5_speed.run(
            datasets=("ego-facebook",) if quick else ("ego-facebook",),
            scale=0.1 if quick else 0.25,
        )
        if not quick:
            fig5_speed.run(datasets=("dblp",), scale=0.01)

    if "fig6" in suites:
        from benchmarks import fig6_scalability
        print("# --- Fig. 6: scalability -----------------------------------")
        fig6_scalability.run(
            scales=(0.005, 0.01, 0.02) if quick else (0.01, 0.02, 0.04, 0.08),
            T=3 if quick else 5,
        )

    if "fig8" in suites:
        from benchmarks import fig8_iterations
        print("# --- Fig. 8: iterations ------------------------------------")
        fig8_iterations.run(
            scale=0.01 if quick else 0.02,
            targets=(0.3, 0.8) if quick else (0.3, 0.5, 0.8),
        )

    if "fidelity" in suites:
        from benchmarks import fidelity
        print("# --- fidelity: vectorized vs sequential oracle --------------")
        # the oracle is the O(small-graph) sequential Alg. 1/2 — sizes are
        # capped accordingly (same rationale as fig4)
        fidelity.run(
            datasets=("ego-facebook",) if quick else ("ego-facebook",),
            scale=0.05 if quick else 0.1,
            k_fracs=(0.3,) if quick else (0.3, 0.5),
            T=10 if quick else 20,
        )
        if not quick:
            fidelity.run(datasets=("dblp",), scale=0.01, k_fracs=(0.3,), T=20)

    if "kernels" in suites:
        from benchmarks import kernelbench
        print("# --- kernels: merge-gain throughput ------------------------")
        kernelbench.run(sizes=((64, 32, 128),) if quick
                        else ((256, 32, 128), (64, 64, 256)))

    if "roofline" in suites:
        from benchmarks import roofline
        print("# --- roofline: dry-run artifact table ----------------------")
        roofline.run()

    print(f"# total bench wall: {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
