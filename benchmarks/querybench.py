"""Query-serving throughput: batched device engine vs single-query numpy.

    PYTHONPATH=src python -m benchmarks.querybench --batches 1 8 64 256

Summarizes the benchmark graph once, then serves the same mixed workload
(degree / adjacency / PageRank / k-hop / cut / conductance probes) three
ways:

  * ``numpy``        — one `repro.core.queries` call per request, the
    status-quo single-query path (block build memoized; the PageRank probe
    pays a full power iteration per call, which is exactly what a serving
    layer exists to amortize);
  * ``numpy-cached`` — same, but with the PageRank vector hand-cached
    across requests: the best a host-side loop can do;
  * ``jax``          — the batched :class:`QueryEngine` at each ``--batches``
    slot width through the `launch.query_serve` scheduler.

The analytics kinds (k-hop / cut / conductance) are swept as a second
workload under their own ``(engine, batch)`` keys (``numpy-analytics`` /
``jax-analytics``) so the original ≥10× point-query gate keeps its
calibration while the new kernels get the same regression coverage.

Rows land in ``artifacts/bench/querybench.json`` (bench="querybench") for
the `scripts/check_bench.py --bench querybench` regression gate;
``--min-speedup`` turns the measured jax-vs-numpy ratio at
``--min-speedup-batch`` into a hard exit-status gate (CI: ≥10× at 64).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.core import SummaryConfig, summarize
from repro.core import queries as Q
from repro.core.queries_jax import (
    KIND_ADJACENCY,
    KIND_CONDUCTANCE,
    KIND_CUT,
    KIND_DEGREE,
    KIND_KHOP,
    KIND_PAGERANK,
    QueryEngine,
)
from repro.graphs import load_graph
from repro.launch.query_serve import QueryServer, random_workload

# the original point-query mix (the ≥10×-vs-numpy CI gate is calibrated
# on it) and the PR-10 analytics mix, swept separately under their own
# (engine, batch) baseline keys
KINDS = [KIND_DEGREE, KIND_ADJACENCY, KIND_PAGERANK]
KINDS_ANALYTICS = [KIND_KHOP, KIND_CUT, KIND_CONDUCTANCE]


def numpy_serve(res, reqs, pagerank_iters: int, cache_pagerank: bool):
    """Answer the request list one query at a time on the host."""
    pr = None
    out = np.zeros(len(reqs))
    for i, req in enumerate(reqs):
        if req.kind == KIND_DEGREE:
            out[i] = Q.expected_degree(res, req.u)
        elif req.kind == KIND_ADJACENCY:
            out[i] = Q.adjacency_weight(res, req.u, req.v)
        elif req.kind == KIND_KHOP:
            out[i] = Q.k_hop_size(res, req.u, req.v)
        elif req.kind == KIND_CUT:
            out[i] = Q.cut_weight(res, req.a, req.b)
        elif req.kind == KIND_CONDUCTANCE:
            out[i] = Q.conductance(res, req.a)
        else:
            if cache_pagerank:
                if pr is None:
                    pr = Q.pagerank_summary(res, iters=pagerank_iters)
                out[i] = pr[req.u]
            else:
                out[i] = Q.pagerank_summary(res, iters=pagerank_iters)[req.u]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="ego-facebook")
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--k-frac", type=float, default=0.4)
    ap.add_argument("--T", type=int, default=6)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--numpy-requests", type=int, default=128,
                    help="request count for the numpy baselines (each "
                         "PageRank probe is a full power iteration)")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8, 64, 256])
    ap.add_argument("--pagerank-iters", type=int, default=50)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit 1 unless jax/numpy QPS ratio reaches this "
                         "at --min-speedup-batch (the CI acceptance gate)")
    ap.add_argument("--min-speedup-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = load_graph(args.dataset, scale=args.scale, seed=args.seed)
    src, dst, v = np.asarray(g.src), np.asarray(g.dst), g.num_nodes
    res = summarize(src, dst, v, SummaryConfig(
        T=args.T, k_frac=args.k_frac, group_size=args.group_size,
        seed=args.seed), collect_history=False)
    rng = np.random.default_rng(args.seed)
    rows = []

    # ---- numpy single-query baselines ---------------------------------
    np_reqs = random_workload(rng, v, args.numpy_requests, KINDS)
    Q.build_block_summary(res)  # memoized build outside the timed window
    for cached, label in ((False, "numpy"), (True, "numpy-cached")):
        t0 = time.perf_counter()
        numpy_serve(res, np_reqs, args.pagerank_iters, cached)
        wall = time.perf_counter() - t0
        qps = len(np_reqs) / max(wall, 1e-9)
        rows.append({"bench": "querybench", "engine": label, "batch": 1,
                     "query": "mixed", "requests": len(np_reqs),
                     "qps": qps, "wall_s": wall})
        emit(rows[-1])
    numpy_qps = rows[0]["qps"]

    # ---- analytics numpy baseline (cached PageRank is irrelevant here) --
    an_reqs = random_workload(rng, v, args.numpy_requests, KINDS_ANALYTICS)
    t0 = time.perf_counter()
    numpy_serve(res, an_reqs, args.pagerank_iters, True)
    wall = time.perf_counter() - t0
    an_numpy_qps = len(an_reqs) / max(wall, 1e-9)
    rows.append({"bench": "querybench", "engine": "numpy-analytics",
                 "batch": 1, "query": "analytics",
                 "requests": len(an_reqs), "qps": an_numpy_qps,
                 "wall_s": wall})
    emit(rows[-1])

    # ---- batched device engine across slot widths ---------------------
    engine = QueryEngine(res, pagerank_iters=args.pagerank_iters)
    speedup_at_gate = None
    sweeps = (("jax", "mixed", KINDS, numpy_qps),
              ("jax-analytics", "analytics", KINDS_ANALYTICS,
               an_numpy_qps))
    for label, query, sweep_kinds, base_qps in sweeps:
        for batch in args.batches:
            server = QueryServer(engine, slots=batch)
            for req in random_workload(rng, v, batch, sweep_kinds):
                server.submit(req)  # compile outside the timed window
            while server.step():
                pass
            server.done.clear()
            reqs = random_workload(rng, v, args.requests, sweep_kinds)
            t0 = time.perf_counter()
            for req in reqs:
                server.submit(req)
            while server.step():
                pass
            wall = time.perf_counter() - t0
            lat = np.array([r.t_done - r.t_submit for r in server.done])
            qps = len(reqs) / max(wall, 1e-9)
            speedup = qps / base_qps
            rows.append({"bench": "querybench", "engine": label,
                         "batch": batch, "query": query,
                         "requests": len(reqs), "qps": qps,
                         "p50_latency_s": float(np.percentile(lat, 50)),
                         "p99_latency_s": float(np.percentile(lat, 99)),
                         "speedup_vs_numpy": speedup, "wall_s": wall})
            emit(rows[-1])
            if (label == "jax" and batch >= args.min_speedup_batch
                    and speedup_at_gate is None):
                speedup_at_gate = speedup

    path = save_artifact("querybench", rows)
    print(f"saved {path}")

    if args.min_speedup is not None:
        if speedup_at_gate is None:
            print(f"no batch >= {args.min_speedup_batch} was measured")
            return 1
        if speedup_at_gate < args.min_speedup:
            print(f"speedup gate FAILED: {speedup_at_gate:.1f}x < "
                  f"{args.min_speedup:.1f}x at batch "
                  f">= {args.min_speedup_batch}")
            return 1
        print(f"speedup gate ok: {speedup_at_gate:.1f}x >= "
              f"{args.min_speedup:.1f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
