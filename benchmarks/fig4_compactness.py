"""Fig. 4 / Fig. 7 (and Fig. 1's point): size-vs-error trade-off curves.

SSumM sweeps the bit budget k ∈ {10%..60%}·Size(G); competitors sweep the
supernode count ∈ {10%..60%}·|V| (their native knob, per Sect. 4.1). Both
RE₁ (Fig. 4) and RE₂ (Fig. 7) are reported for every point. Datasets are
the offline synthetic stand-ins (graphs/synthetic.py).
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, quality, run_baseline, run_ssumm, save_artifact
from repro.graphs import generate

DEFAULT_FRACS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)


def run(datasets=("ego-facebook",), scale=0.25, fracs=DEFAULT_FRACS,
        methods=("ssumm", "kgs", "s2l", "saa_gs"), seed: int = 0,
        T: int = 20) -> list[dict]:
    rows = []
    for ds in datasets:
        src, dst, v = generate(ds, seed=seed, scale=scale)
        per_ds: list[dict] = []
        for frac in fracs:
            for m in methods:
                if m == "ssumm":
                    r = run_ssumm(src, dst, v, k_frac=frac, T=T, seed=seed)
                else:
                    r = run_baseline(m, src, dst, v, frac, seed=seed)
                r.update({"bench": "fig4", "dataset": ds, "V": v, "E": len(src)})
                per_ds.append(r)
                emit(r)
        quality(per_ds)
        rows.extend(per_ds)
    save_artifact("fig4_compactness", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", nargs="+", default=["ego-facebook", "dblp"])
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--fracs", nargs="+", type=float, default=list(DEFAULT_FRACS))
    ap.add_argument("--methods", nargs="+",
                    default=["ssumm", "kgs", "s2l", "saa_gs"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.datasets, args.scale, tuple(args.fracs), tuple(args.methods),
        args.seed)


if __name__ == "__main__":
    main()
