"""Distributed SSumM: edge-sharded summarization under shard_map.

    python examples/distributed_summarize.py      # no PYTHONPATH needed

Spawns 8 placeholder devices (the same mechanism the multi-pod dry-run
uses at 512), shards the edge list over a (2, 4) mesh, and runs the
paper's iteration loop through the SummaryEngine (DESIGN.md §12) over the
edge-sharded DistributedBackend: all_to_all pair-exchange + owner-local
merge rounds, with up to ``cfg.driver_chunk`` rounds per device dispatch
(lax.while_loop inside the shard_map body). The replicated partition and
the global metrics match the single-device path (see tests/dist_check.py
for the exact-parity assertions).
"""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import SummaryConfig
from repro.core.distributed import make_distributed_backend
from repro.core.engine import SummaryEngine
from repro.core.types import make_graph
from repro.graphs import generate
from repro.graphs.feed import shard_edges
from repro.launch.mesh import make_host_mesh


def main():
    src, dst, v = generate("dblp", seed=0, scale=0.02)
    graph, _ = make_graph(src, dst, v)
    e = graph.num_edges
    size_g = 2.0 * e * np.log2(max(v, 2))
    print(f"graph: |V|={v} |E|={e}  Size(G)={size_g:,.0f} bits")
    print(f"devices: {jax.device_count()} → mesh (2, 4) = (data, model)")

    mesh = make_host_mesh((2, 4), ("data", "model"))
    cfg = SummaryConfig(T=10, k_frac=0.3)
    # compact group-owner sharding (the web-scale path, DESIGN.md §7);
    # small graphs need a generous routing capacity (few groups → skew)
    backend = make_distributed_backend(mesh, cfg, v, e, grouping="compact",
                                       capacity_factor=32.0, lean_sort=True)
    # per-shard feed (DESIGN.md §11): shards are born on their devices;
    # real graphs would come off the mmap'd CSR cache the same way via
    # shard_edges_from_cache(cache_dir, mesh) — zero host densify
    shards = shard_edges(np.asarray(graph.src), np.asarray(graph.dst), mesh)
    print(f"edge shard per device: {shards.stats.shard_rows} edges "
          f"(host staging {shards.stats.peak_staging_bytes} B — one shard)")

    # the engine owns Alg. 1: θ schedule, stopping rule, chunked driver,
    # and the Sect. 3.2.4 drop-to-k finalize (edge-sharded ξ-th order
    # statistic — no host-side gather; DESIGN.md §7/§12)
    run = SummaryEngine(backend.bind(shards.src, shards.dst)).run()
    k_bits = run.k_bits
    for row in run.history:
        print(f"  t={int(row['t']):2d} θ={row['theta']:.2f} "
              f"|S|={int(row['num_supernodes']):5d} "
              f"size={row['size_bits']:12,.0f} bits "
              f"({100 * row['size_bits'] / size_g:5.1f}%) "
              f"merges={int(row['nmerges']):4d} "
              f"overflow={int(row['overflow'])}")
    if run.last_stats and run.last_stats["size_bits"] <= k_bits:
        print("  budget reached")

    sp_stats = run.finalize["stats"]
    print(f"sparsify: ξ={int(float(sp_stats['xi']))} "
          f"dropped={int(float(sp_stats['dropped']))} superedges → "
          f"size={float(sp_stats['size_bits']):12,.0f} bits "
          f"({100 * float(sp_stats['size_bits']) / size_g:5.1f}%) "
          f"RE₁={float(sp_stats['re1']):.4f}")


if __name__ == "__main__":
    main()
