"""End-to-end LM training driver example (deliverable b): trains a ~100M
decoder-only model for a few hundred steps on the synthetic corpus with
checkpointing enabled, then resumes once to demonstrate restart.

    PYTHONPATH=src python examples/train_lm.py                 # full (~100M)
    PYTHONPATH=src python examples/train_lm.py --tiny          # CI-sized

The full setting instantiates h2o-danube's family at ~100M params (the
assigned config scaled down in width only — same code path as the 1.8B).
"""

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "h2o_danube_1_8b", "--smoke",
                "--steps", str(args.steps or 30), "--batch", "4",
                "--seq", "64", "--lr", "1e-3"]
    else:
        # ~100M-parameter member of the danube family, full vocab
        import repro.configs.h2o_danube_1_8b as danube

        cfg100 = dataclasses.replace(
            get_config("h2o_danube_1_8b"),
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
            dtype="float32",
        )
        danube_smoke, danube.smoke = danube.smoke, (lambda: cfg100)
        argv = ["--arch", "h2o_danube_1_8b", "--smoke",
                "--steps", str(args.steps or 300), "--batch", "8",
                "--seq", "256", "--lr", "6e-4"]

    with tempfile.TemporaryDirectory() as ckpt:
        argv += ["--ckpt-dir", ckpt, "--ckpt-every", "50"]
        res = train_driver.main(argv)
        print(f"\nfirst loss {res['loss_first']:.3f} → "
              f"last loss {res['loss_last']:.3f} "
              f"({res['wall_s']:.0f}s, {res['steps']} steps)")
        assert res["loss_last"] < res["loss_first"], "loss must decrease"


if __name__ == "__main__":
    main()
