"""Quickstart: summarize a graph within a bit budget with SSumM.

    PYTHONPATH=src python examples/quickstart.py

Builds a community-structured graph, runs SSumM with a 30% budget, prints
the paper's metrics (Eq. 2 / Eq. 4), and reconstructs a few node
neighborhoods from the summary to show the summary graph stays analyzable.
"""

import numpy as np

from repro.core import SummaryConfig, summarize
from repro.graphs import generate


def main():
    # a small social-like graph (ego-facebook stand-in at 10% scale)
    src, dst, v = generate("ego-facebook", seed=0, scale=0.1)
    print(f"input graph: |V|={v} |E|={len(src)}")

    res = summarize(src, dst, v, SummaryConfig(T=20, k_frac=0.3, seed=0))

    print(f"summary: |S|={res.num_supernodes} |P|={res.num_superedges}")
    print(f"size: {res.size_bits:,.0f} bits "
          f"({100 * res.size_bits / res.input_size_bits:.1f}% of input, "
          f"budget was 30%)")
    print(f"reconstruction error: RE1={res.re1:.2e} RE2={res.re2:.2e}")
    print(f"iterations: {res.iterations_run}")

    # --- analytics served from the summary (paper benefit (b)) ----------
    from repro.core.queries import expected_degree, pagerank_summary

    deg = np.zeros(v)
    np.add.at(deg, src, 1)
    np.add.at(deg, dst, 1)
    print("\nqueries from the summary (no reconstruction):")
    for u in np.argsort(-deg)[:5]:
        print(f"  node {u:4d}: true degree {int(deg[u]):4d}, "
              f"summary estimate {expected_degree(res, int(u)):7.1f}")

    pr = pagerank_summary(res)
    top = np.argsort(-pr)[:5]
    print("  top-PageRank nodes (block-space power iteration):",
          ", ".join(f"{int(u)} ({pr[u]:.2e})" for u in top))


if __name__ == "__main__":
    main()
