"""Serving example: continuous-batching decode over the Model API.

    PYTHONPATH=src python examples/serve_lm.py

Submits a burst of requests against a reduced gemma-family model and
reports throughput / latency percentiles from the BatchServer scheduler
(the production shardings for this path are exercised by the decode_32k /
long_500k dry-run cells).
"""

from repro.launch import serve as serve_driver


def main():
    res = serve_driver.main([
        "--arch", "gemma_7b", "--smoke",
        "--requests", "12", "--slots", "4",
        "--prompt-len", "16", "--gen-len", "24", "--max-len", "128",
    ])
    print(f"\nthroughput {res['tok_per_s']:.1f} tok/s | "
          f"p50 latency {res['p50_latency_s']*1e3:.0f} ms | "
          f"p50 TTFT {res['p50_ttft_s']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
