"""Cell lowering: (arch × shape × mesh) → jitted step with full shardings.

Shared by the dry-run driver (lower + compile only), the roofline/perf
harness, and the real train/serve drivers (same shardings, real arrays).

A *cell* is one (ModelConfig, ShapeSpec, Mesh) triple; ``lower_cell``
assembles the parameter/optimizer/batch shardings from the logical-axis
trees and returns the ``jax.stages.Lowered`` plus everything needed to
interpret its cost analysis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.configs.base import ModelConfig, RunConfig, SHAPES, ShapeSpec
from repro.dist.sharding import MeshRules, make_rules
from repro.models.api import Model, build_model, input_specs
from repro.optim import adamw_init


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    sp: ShapeSpec
    mesh: Any
    rules: MeshRules
    model: Model
    step_fn: Any  # the function that was lowered
    arg_structs: tuple  # eval_shape inputs
    arg_shardings: tuple


def _tree_shardings(rules: MeshRules, structs, axes):
    return jax.tree.map(
        lambda s, a: rules.sharding(a, s.shape),
        structs,
        axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _batch_shardings(rules: MeshRules, batch):
    def one(s):
        if len(s.shape) == 2:
            logical = ("batch", "seq")
        elif len(s.shape) == 3:
            logical = ("batch", "seq", None)
        else:
            logical = ("batch",)
        return rules.sharding(logical, s.shape)

    return jax.tree.map(one, batch, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_cell(
    cfg: ModelConfig,
    shape: str | ShapeSpec,
    mesh,
    *,
    run: RunConfig | None = None,
    remat: bool = True,
    rules: MeshRules | None = None,
) -> Cell:
    sp = SHAPES[shape] if isinstance(shape, str) else shape
    mode = "train" if sp.kind == "train" else "serve"
    rules = rules or make_rules(mesh, mode)
    model = build_model(cfg)
    run = run or RunConfig()

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.axes()
    p_shard = _tree_shardings(rules, params_s, axes)
    batch = input_specs(cfg, sp.name)

    if sp.kind == "train":
        opt_s = jax.eval_shape(adamw_init, params_s)
        o_shard = type(opt_s)(
            step=rules.sharding((), ()),
            mu=_tree_shardings(rules, opt_s.mu, axes),
            nu=_tree_shardings(rules, opt_s.nu, axes),
        )
        b_shard = _batch_shardings(rules, batch)

        def step(params, opt, b):
            return model.train_step(params, opt, b, rules, run, remat=remat)

        return Cell(cfg, sp, mesh, rules, model, step,
                    (params_s, opt_s, batch), (p_shard, o_shard, b_shard))

    if sp.kind == "decode":
        cache_axes = model.cache_axes()
        b_shard = {
            "token": rules.sharding(("batch",), (sp.global_batch,)),
            "pos": rules.sharding((), ()),
            "cache": jax.tree.map(
                lambda s, a: rules.sharding(a, s.shape),
                batch["cache"], cache_axes,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            ),
        }

        def step(params, b):
            return model.serve_step(params, b, rules)

        return Cell(cfg, sp, mesh, rules, model, step,
                    (params_s, batch), (p_shard, b_shard))

    # prefill
    b_shard = _batch_shardings(rules, batch)

    def step(params, b):
        return model.prefill_step(params, b, rules)

    return Cell(cfg, sp, mesh, rules, model, step,
                (params_s, batch), (p_shard, b_shard))


def lower_cell(cell: Cell, *, donate: bool = True):
    """Lower the cell's step under its mesh. Zero device allocation."""
    donate_argnums: tuple = ()
    if donate:
        # params+opt for train (in-place update), cache holder for decode
        donate_argnums = (0, 1) if cell.sp.kind == "train" else ()
    jitted = jax.jit(
        cell.step_fn,
        in_shardings=cell.arg_shardings,
        donate_argnums=donate_argnums,
    )
    with cell.mesh:
        return jitted.lower(*cell.arg_structs)
