"""Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture × input shape) on the production meshes and record the
roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh pod      # 16×16 only
    PYTHONPATH=src python -m repro.launch.dryrun --summary       # table only

Artifacts: one JSON per cell under ``artifacts/dryrun/`` holding
``memory_analysis()``, ``cost_analysis()``, and the per-device collective
bytes parsed from the compiled HLO — EXPERIMENTS.md §Dry-run/§Roofline read
these. Completed cells are skipped (resumable); use ``--force`` to redo.
"""

# The container exposes ONE real CPU device; the dry-run needs 512
# placeholder devices for the production meshes. Must precede ANY jax
# import (jax locks the device count on first init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.dist.sharding import make_rules
from repro.launch import costs as rcosts
from repro.launch.lowering import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh, mesh_device_count

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def apply_variants(cfg, mesh, shape, variants: dict):
    """Perf-iteration knobs: patch the config / sharding rules.

    Supported keys:
      moe_impl=a2a|gspmd      — MoE dispatch path (models/moe.py)
      seq=model|none          — activation sequence axis (serve SP)
      kvseq=model|none        — decode cache sharding axis
      batch=...               — e.g. batch=data,model for wider DP
      remat=0|1
    """
    import dataclasses

    from repro.configs.base import SHAPES

    rules = None
    overrides = {}
    for key, val in variants.items():
        if key == "moe_impl" and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, impl=val))
        elif key in ("seq", "kvseq", "batch", "act_embed", "embed",
                     "attn_embed", "heads", "kv_heads", "ff", "vocab",
                     "experts"):
            if val == "none":
                overrides[key] = None
            else:
                parts = tuple(val.split("+"))
                overrides[key] = parts if len(parts) > 1 else parts[0]
    if overrides:
        sp = SHAPES[shape]
        mode = "train" if sp.kind == "train" else "serve"
        rules = make_rules(mesh, mode, overrides=overrides)
    return cfg, rules


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             force: bool = False, remat: bool = True,
             tag: str = "", variants: dict | None = None) -> dict:
    """Lower+compile one cell; returns (and persists) the record."""
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh_device_count(mesh)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "n_devices": n_dev,
        "status": "error", "tag": tag, "variants": variants or {},
    }
    try:
        rules = None
        if variants:
            cfg, rules = apply_variants(cfg, mesh, shape, variants)
            rv = variants.get("remat")
            if rv is not None:
                remat = {"none": False, "full": True}.get(rv, rv)
        t0 = time.time()
        cell = build_cell(cfg, shape, mesh, remat=remat, rules=rules)
        lowered = lower_cell(cell)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        hlo = compiled.as_text()
        rec["collectives"] = rcosts.collective_bytes(hlo)
        rec["hlo_bytes"] = len(hlo)
        rec["roofline"] = rcosts.roofline(
            hlo_flops_per_dev=rec["cost"]["flops"],
            hlo_bytes_per_dev=rec["cost"]["bytes_accessed"],
            coll_bytes_per_dev=rec["collectives"]["total"],
            # "dots" saves attention matmuls → backward does not rerun
            # them; the analytic scan correction must then use mult=3
            cfg=cfg, sp=cell.sp, n_chips=n_dev,
            remat=(remat is True or remat == "full"),
        )
        rec["status"] = "ok"
        del compiled, lowered, cell
    except Exception as e:  # recorded, not raised — the sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_ssumm_cell(dataset: str, mesh_kind: str, out_dir: str,
                   force: bool = False, group_size: int = 64,
                   tag: str = "", lean_sort: bool = False,
                   regroup_every: int = 0) -> dict:
    """Lower+compile one *distributed SSumM iteration* at web scale — the
    paper-representative roofline cell (DESIGN.md §7; compact group-owner
    sharding). MODEL_FLOPS here = the merge-gain scoring arithmetic
    (G·C²·(14·U+10) per iteration), the algorithm's useful work."""
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"ssumm_{dataset}__iteration__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    import jax.numpy as jnp

    from repro.core.distributed import make_distributed_step_compact
    from repro.core.types import SummaryConfig
    from repro.graphs import DATASETS

    spec = DATASETS[dataset]
    v, e = spec.v, spec.e_target
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh_device_count(mesh)
    e_pad = -(-e // n_dev) * n_dev
    cfg = SummaryConfig(group_size=group_size)
    rec = {
        "arch": f"ssumm_{dataset}", "shape": "iteration", "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "n_devices": n_dev, "V": v, "E": e,
        "status": "error", "tag": tag,
    }
    try:
        split = regroup_every > 1
        step = make_distributed_step_compact(mesh, cfg, v, e,
                                             lean_sort=lean_sort,
                                             external_groups=split)
        i32, u32, f32 = jnp.int32, jnp.uint32, jnp.float32
        from repro.core.types import SummaryState

        state_s = SummaryState(
            node2super=jax.ShapeDtypeStruct((v,), i32),
            size=jax.ShapeDtypeStruct((v,), i32),
            rng=jax.ShapeDtypeStruct((2,), u32),
            t=jax.ShapeDtypeStruct((), i32),
        )
        g_total = -(-v // group_size)
        g_pad = -(-g_total // n_dev) * n_dev
        step_args = [
            jax.ShapeDtypeStruct((e_pad,), i32),
            jax.ShapeDtypeStruct((e_pad,), i32),
            state_s,
            jax.ShapeDtypeStruct((), f32),
            jax.ShapeDtypeStruct((), u32),
        ]
        if split:
            step_args.append(jax.ShapeDtypeStruct((g_pad, group_size), i32))
        t0 = time.time()
        with mesh:
            lowered = step.lower(*step_args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        flops = float(ca.get("flops", 0.0))
        bts = float(ca.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        coll = rcosts.collective_bytes(hlo)
        rec["cost"] = {"flops": flops, "bytes_accessed": bts}
        rec["collectives"] = coll
        if split:
            # amortize the standalone grouping program over regroup_every
            from repro.core.distributed import make_grouping_fn

            gfn = make_grouping_fn(mesh, cfg, v, lean_sort=lean_sort)
            with mesh:
                gcomp = gfn.lower(*step_args[:3]).compile()
            gca = gcomp.cost_analysis() or {}
            gcoll = rcosts.collective_bytes(gcomp.as_text())
            rec["grouping_cost"] = {
                "flops": float(gca.get("flops", 0.0)),
                "bytes_accessed": float(gca.get("bytes accessed", 0.0)),
                "collective_bytes": gcoll["total"],
                "regroup_every": regroup_every,
            }
            flops += rec["grouping_cost"]["flops"] / regroup_every
            bts += rec["grouping_cost"]["bytes_accessed"] / regroup_every
            coll = dict(coll)
            coll["total"] += gcoll["total"] / regroup_every
            del gcomp
        g_total = -(-v // group_size)
        useful = g_total * group_size**2 * (14.0 * cfg.union_size + 10.0)
        t_c = flops / rcosts.PEAK_FLOPS
        t_m = bts / rcosts.HBM_BW
        t_l = coll["total"] / rcosts.ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_l}
        rec["roofline"] = {
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_l,
            "bottleneck": max(terms, key=terms.get),
            "model_flops": useful,
            "hlo_flops_total": flops * n_dev,
            "useful_ratio": useful / max(flops * n_dev, 1.0),
            "roofline_fraction": (useful / (n_dev * rcosts.PEAK_FLOPS))
            / max(max(terms.values()), 1e-12),
            "step_time_bound_s": max(terms.values()),
        }
        rec["status"] = "ok"
        del compiled, lowered
    except Exception as exc:
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def iter_cells(archs, shapes_filter=None):
    for arch in archs:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            if shapes_filter and shape not in shapes_filter:
                continue
            yield arch, shape


def summarize(out_dir: str) -> None:
    rows = []
    for fn in sorted(os.listdir(out_dir)) if os.path.isdir(out_dir) else []:
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            r = json.load(f)
        if r.get("tag"):
            continue  # perf-iteration variants are reported in §Perf
        rows.append(r)
    hdr = (f"{'arch':<22} {'shape':<12} {'mesh':<9} {'status':<7} "
           f"{'compile_s':>9} {'t_comp':>9} {'t_mem':>9} {'t_coll':>9} "
           f"{'bottleneck':<11} {'roofline%':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] == "ok":
            rf = r["roofline"]
            print(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<9} ok      "
                  f"{r.get('compile_s', 0):>9.1f} {rf['t_compute']:>9.2e} "
                  f"{rf['t_memory']:>9.2e} {rf['t_collective']:>9.2e} "
                  f"{rf['bottleneck']:<11} {100*rf['roofline_fraction']:>8.1f}%")
        else:
            print(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<9} ERROR   "
                  f"{r.get('error', '')[:60]}")
    n_ok = sum(r["status"] == "ok" for r in rows)
    print(f"\n{n_ok}/{len(rows)} cells ok")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="architecture id(s)")
    ap.add_argument("--shape", action="append", help="input shape(s)")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="both")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--summary", action="store_true", help="print table only")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--variant", action="append", default=[],
                    help="perf knob key=value (see apply_variants)")
    ap.add_argument("--ssumm", default="",
                    help="dataset name: dry-run the distributed SSumM "
                         "iteration instead of LM cells (e.g. web-uk-05)")
    ap.add_argument("--ssumm-group-size", type=int, default=64)
    args = ap.parse_args()
    variants = dict(v.split("=", 1) for v in args.variant)

    if args.summary:
        summarize(args.out)
        return

    assert jax.device_count() == 512, (
        f"dry-run needs 512 host devices, got {jax.device_count()} — "
        "XLA_FLAGS was set too late"
    )
    archs = args.arch or ARCHS
    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    failures = []
    if args.ssumm:
        for mesh_kind in meshes:
            t0 = time.time()
            rec = run_ssumm_cell(args.ssumm, mesh_kind, args.out,
                                 force=args.force,
                                 group_size=args.ssumm_group_size,
                                 tag=args.tag,
                                 lean_sort=("lean_sort" in variants),
                                 regroup_every=int(variants.get(
                                     "regroup_every", 0)))
            print(f"[{time.strftime('%H:%M:%S')}] ssumm_{args.ssumm} "
                  f"{mesh_kind}: {rec['status']} ({time.time()-t0:.0f}s)",
                  flush=True)
            if rec["status"] != "ok":
                print(rec.get("error"))
                failures.append(("ssumm", args.ssumm, mesh_kind))
        if failures:
            raise SystemExit(1)
        return
    for arch, shape in iter_cells(archs, args.shape):
        for mesh_kind in meshes:
            t0 = time.time()
            rec = run_cell(arch, shape, mesh_kind, args.out,
                           force=args.force, remat=not args.no_remat,
                           tag=args.tag, variants=variants)
            status = rec["status"]
            print(f"[{time.strftime('%H:%M:%S')}] {arch} {shape} {mesh_kind}: "
                  f"{status} ({time.time()-t0:.0f}s)", flush=True)
            if status != "ok":
                failures.append((arch, shape, mesh_kind, rec.get("error")))
    summarize(args.out)
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", *f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
