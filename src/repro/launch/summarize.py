"""Graph-summarization driver (the paper's own workload).

    PYTHONPATH=src python -m repro.launch.summarize --dataset dblp \
        --scale 0.05 --k-frac 0.3 --T 20

Runs SSumM (the vectorized TPU-native implementation) on a registry graph,
optionally distributed over every local device with the edge-sharded
shard_map path (``--distributed``), and prints Eq.(2)/(4) metrics.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.distributed import (
    make_distributed_step_compact,
    pad_and_shard_edges,
)
from repro.core.types import init_state, make_graph
from repro.graphs import DATASETS, generate
from repro.runtime import make_mesh_from_plan, plan_mesh


def run_distributed(src, dst, v, cfg: SummaryConfig, mesh):
    graph, _ = make_graph(src, dst, v)
    e = graph.num_edges
    src_p, dst_p = pad_and_shard_edges(np.asarray(graph.src),
                                       np.asarray(graph.dst), mesh)
    step = make_distributed_step_compact(mesh, cfg, v, e,
                                         capacity_factor=32.0,
                                         lean_sort=True)
    state = init_state(v, cfg.seed)
    size_g = 2.0 * e * float(np.log2(max(v, 2)))
    k_bits = cfg.target_bits(size_g)
    stats = {}
    with mesh:
        for t in range(1, cfg.T + 1):
            theta = 1.0 / (1.0 + t) if t < cfg.T else 0.0
            state, stats = step(src_p, dst_p, state,
                                jnp.asarray(theta, jnp.float32),
                                jnp.asarray(t, jnp.uint32))
            if float(stats["size_bits"]) <= k_bits:
                break
    return state, {k: float(x) for k, x in stats.items()}, size_g


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--scale", type=float, default=0.05,
                    help="subsample factor for the registry |V|,|E|")
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--distributed", action="store_true",
                    help="edge-sharded shard_map over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    src, dst, v = generate(args.dataset, seed=args.seed, scale=args.scale)
    cfg = SummaryConfig(T=args.T, k_frac=args.k_frac,
                        group_size=args.group_size, seed=args.seed)
    t0 = time.time()
    if args.distributed:
        plan = plan_mesh(jax.device_count(), global_batch=1, want_model=1)
        mesh = make_mesh_from_plan(plan)
        _state, stats, size_g = run_distributed(src, dst, v, cfg, mesh)
        result = {
            "dataset": args.dataset, "V": v, "E": len(src),
            "mode": f"distributed{dict(mesh.shape)}",
            "size_bits": stats["size_bits"],
            "relative_size": stats["size_bits"] / size_g,
            "re1": stats["re1"],
            "num_supernodes": stats["num_supernodes"],
            "wall_s": time.time() - t0,
        }
    else:
        res = summarize(src, dst, v, cfg)
        result = {
            "dataset": args.dataset, "V": v, "E": len(src), "mode": "local",
            "size_bits": res.size_bits,
            "relative_size": res.size_bits / res.input_size_bits,
            "re1": res.re1, "re2": res.re2,
            "num_supernodes": res.num_supernodes,
            "num_superedges": res.num_superedges,
            "iterations": res.iterations_run,
            "wall_s": time.time() - t0,
        }
    print(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    main()
