"""Graph-summarization driver (the paper's own workload).

    PYTHONPATH=src python -m repro.launch.summarize --dataset dblp \
        --scale 0.05 --k-frac 0.3 --T 20

    PYTHONPATH=src python -m repro.launch.summarize \
        --edge-list data/dblp.txt.gz --k-frac 0.3 --T 20

Runs SSumM (the vectorized TPU-native implementation) on a registry graph
or a real SNAP edge-list file (``--edge-list``; streamed + CSR-cached via
``repro.graphs.io``, DESIGN.md §10), optionally distributed over every
local device with the edge-sharded shard_map path (``--distributed``),
and prints Eq.(2)/(4) metrics. Registry names resolve real files under
``$SSUMM_DATA_DIR`` first, then the binary cache, then the synthetic
stand-in — the JSON's ``source`` field says which one ran.

Distributed runs with a CSR cache behind them feed the mmap'd edge
columns straight onto the mesh (``repro.graphs.feed``, DESIGN.md §11):
host staging is one shard, never a full-|E| array, and the JSON reports
the feed accounting (``feed_*``) plus ``peak_rss_mb``; ``--rss-budget-mb``
turns the RSS number into a hard exit-status gate (the CI ``ingest`` job
runs the 1.1M-edge fixture under it).

The mesh may span OS processes (DESIGN.md §15): launch the same command
on every host with ``--coordinator host:port --num-processes N
--process-id i`` (or the ``SSUMM_*`` env equivalents) plus
``--distributed``; each process then stages only its addressable shards
from the shared CSR cache and the summary is bit-identical to the
single-process run on the same global device count
(``tests/multihost_check.py``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.distributed import make_distributed_backend
from repro.core.engine import EngineCheckpointer, SummaryEngine
from repro.core.types import make_graph
from repro.graphs import DATASETS, load_graph
from repro.graphs.feed import (
    EdgeShards,
    shard_edges,
    shard_edges_from_cache,
    shard_edges_from_cache_multihost,
)
from repro.launch.mesh import bootstrap_distributed
from repro.runtime import (
    RESUMABLE_EXIT,
    CheckpointManager,
    Preempted,
    PreemptionGuard,
    StragglerMonitor,
    make_mesh_from_plan,
    plan_mesh,
)


def build_distributed_pipeline(mesh, cfg: SummaryConfig, num_nodes: int,
                               num_edges: int):
    """The jitted distributed backend for one problem size (DESIGN.md §12).

    Each call builds *fresh* jit closures — callers that run the pipeline
    repeatedly at the same shapes (benchmarks timing warm runs) must build
    once and pass the backend to :func:`run_distributed`, otherwise every
    run retraces and recompiles.
    """
    return make_distributed_backend(mesh, cfg, num_nodes, num_edges,
                                    grouping="compact",
                                    capacity_factor=32.0,
                                    lean_sort=True)


def run_distributed(src, dst, v, cfg: SummaryConfig, mesh, pipeline=None,
                    shards: EdgeShards | None = None, *,
                    checkpointer=None, monitor=None, resume: bool = False):
    """Merge rounds + final sparsification, all edge-sharded over ``mesh``.

    Eq.(2)/(4) metrics come out of the psum'd reductions of the sparsify
    step — at no point is the edge list (or the pair table) gathered to a
    single host. Returns ``(state, stats, size_g)`` with ``stats`` holding
    the post-sparsification metrics plus ``sparsify_wall_s``.

    ``shards`` (an :class:`repro.graphs.feed.EdgeShards`) supplies the
    already-sharded edge columns — the out-of-core path
    (``shard_edges_from_cache``) or a benchmark reusing one feed across
    rounds. ``src``/``dst`` are then ignored (pass ``None``). Without it,
    the edge list is canonicalized and fed through the in-memory fallback;
    both paths produce bit-identical metrics (``tests/feed_check.py``).

    The loop itself is :class:`repro.core.engine.SummaryEngine` over the
    distributed backend (DESIGN.md §12): ``cfg.driver_chunk`` merge rounds
    run per dispatch inside the shard_map body, and the Sect. 3.2.4
    drop-to-k tail (distributed ξ-th order statistic, DESIGN.md §7) is the
    backend's finalize.

    ``checkpointer``/``monitor``/``resume`` pass through to the engine
    (DESIGN.md §13); the fault-tolerance bookkeeping rides along inside the
    stats dict (``chunk_wall_s``, ``straggler_events``, ``resumed_from``,
    ``checkpoint_*``).
    """
    if shards is None:
        graph, _ = make_graph(src, dst, v)
        shards = shard_edges(np.asarray(graph.src), np.asarray(graph.dst),
                             mesh)
    elif shards.num_nodes is not None and shards.num_nodes != v:
        # a stale v with cache-fed shards would let edge ids index out of
        # the [v]-sized partition vectors, which jit clamps silently —
        # plausible-but-wrong metrics instead of an error
        raise ValueError(
            f"shards came from a cache with |V|={shards.num_nodes} but "
            f"run_distributed was called with v={v}")
    e = shards.num_edges
    if pipeline is None:
        pipeline = build_distributed_pipeline(mesh, cfg, v, e)
    backend = pipeline.bind(shards.src, shards.dst)
    run = SummaryEngine(backend).run(collect_history=False,
                                     checkpointer=checkpointer,
                                     monitor=monitor, resume=resume)
    out = {k: float(x) for k, x in (run.last_stats or {}).items()}
    sp_stats = {k: float(x) for k, x in run.finalize["stats"].items()}
    sp_stats["sparsify_wall_s"] = run.sparsify_wall_s
    out.update(sp_stats)
    out["chunk_wall_s"] = run.chunk_wall_s
    out["straggler_events"] = [dataclasses.asdict(ev)
                               for ev in run.straggler_events]
    out["resumed_from"] = run.resumed_from
    out["checkpoint_saves"] = run.checkpoint_saves
    out["checkpoint_snapshot_wall_s"] = run.checkpoint_snapshot_wall_s
    return run.state, out, run.input_size_bits


def peak_rss_mb() -> float | None:
    """Process high-water RSS in MB (``None`` where unsupported)."""
    try:
        import resource
        import sys

        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is KB on linux, bytes on darwin
        return rss / (1 << 20) if sys.platform == "darwin" else rss / 1024.0
    except (ImportError, ValueError):
        return None


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--edge-list", default=None, metavar="PATH",
                    help="SNAP edge-list file (.txt/.csv, optional .gz); "
                         "overrides --dataset/--scale")
    ap.add_argument("--chunk-edges", type=int, default=None,
                    help="ingest chunk size (rows); bounds parser memory")
    ap.add_argument("--reingest", action="store_true",
                    help="force a re-parse even when the CSR cache is fresh")
    ap.add_argument("--scale", type=float, default=0.05,
                    help="subsample factor for the synthetic registry |V|,|E|")
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--distributed", action="store_true",
                    help="edge-sharded shard_map over all local devices")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address for a "
                         "process-spanning mesh (DESIGN.md §15); every "
                         "process passes the same value")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total processes in the mesh (default: "
                         "$SSUMM_NUM_PROCESSES, else single-process)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank in [0, --num-processes)")
    ap.add_argument("--rss-budget-mb", type=float, default=None,
                    help="fail (exit 1) if the process peak RSS exceeds "
                         "this many MB — the CI out-of-core gate")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="save resumable Alg. 1 state here at chunk "
                         "boundaries (async, atomic, keep-N); SIGTERM/"
                         f"SIGINT then save-and-exit {RESUMABLE_EXIT}")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="save cadence in completed merge rounds, aligned "
                         "up to chunk boundaries (<=0: only the final and "
                         "preemption saves)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="committed checkpoints retained (keep-N GC)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest committed checkpoint in "
                         "--checkpoint-dir (bit-identical to an "
                         "uninterrupted run; re-plans the mesh for the "
                         "current device count)")
    ap.add_argument("--driver-chunk", type=int, default=None,
                    help="merge rounds per device dispatch (default: "
                         "SummaryConfig.driver_chunk)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    # multi-host bootstrap FIRST — jax.distributed.initialize must run
    # before anything queries device state (single-process: no-op)
    dist = bootstrap_distributed(args.coordinator, args.num_processes,
                                 args.process_id)
    if dist.initialized and not args.distributed:
        ap.error("--coordinator/--num-processes only make sense with "
                 "--distributed")

    t_load = time.time()
    g = load_graph(args.edge_list or args.dataset,
                   chunk_edges=args.chunk_edges, refresh=args.reingest,
                   scale=args.scale, seed=args.seed)
    load_wall_s = time.time() - t_load
    src, dst, v = np.asarray(g.src), np.asarray(g.dst), g.num_nodes
    cfg_kw = {} if args.driver_chunk is None else \
        {"driver_chunk": args.driver_chunk}
    cfg = SummaryConfig(T=args.T, k_frac=args.k_frac,
                        group_size=args.group_size, seed=args.seed, **cfg_kw)

    # fault tolerance (DESIGN.md §13): cooperative preemption + chunk-
    # boundary checkpoints; straggler monitor always on (host-side, free)
    monitor = StragglerMonitor()
    monitor.on_straggler(lambda ev: print(
        f"[straggler] dispatch t0={ev.step}: {ev.step_time:.3f}s "
        f"({ev.ratio:.1f}x the {ev.mean:.3f}s EMA)", file=sys.stderr))
    ckp = None
    if args.checkpoint_dir:
        ckp = EngineCheckpointer(
            manager=CheckpointManager(args.checkpoint_dir,
                                      keep=args.checkpoint_keep),
            every=args.checkpoint_every,
            guard=PreemptionGuard(),
            graph_extra={"dataset": args.edge_list or args.dataset},
        )
    ingest = {
        "source": g.source,
        "load_wall_s": load_wall_s,
        "ingest_bytes_parsed": g.stats.bytes_parsed,
        "ingest_chunks": g.stats.chunks,
        "ingest_duplicates_dropped": g.stats.duplicates_dropped,
        "ingest_self_loops_dropped": g.stats.self_loops_dropped,
    }
    t0 = time.time()
    try:
        if args.distributed:
            # elastic re-mesh: the plan always reflects the *current*
            # device count — a resume after device loss lands on the
            # survivor mesh, the replicated state is reshard-on-load, and
            # the edge shards are re-fed below from the mmap cache
            # (DESIGN.md §13); no resharding pass anywhere
            plan = plan_mesh(jax.device_count(), global_batch=1,
                             want_model=1)
            mesh = make_mesh_from_plan(plan)
            # out-of-core feed: a graph backed by a CSR cache goes straight
            # from the mmap'd columns to per-device shards (DESIGN.md §11);
            # only synthetic stand-ins take the in-memory fallback
            t_feed = time.time()
            if dist.process_count > 1:
                # process-spanning mesh: every process slices only its own
                # addressable shards out of the shared cache (DESIGN.md
                # §15) — the single-process feeds refuse this mesh
                if g.cache_dir is None:
                    raise SystemExit(
                        "multi-process summarize needs a CSR-cached graph "
                        "(--edge-list or a cached registry dataset): the "
                        "synthetic in-memory path would materialize the "
                        "full edge list on every host")
                shards = shard_edges_from_cache_multihost(g.cache_dir, mesh)
            elif g.cache_dir is not None:
                shards = shard_edges_from_cache(g.cache_dir, mesh)
            else:
                graph, _ = make_graph(src, dst, v)
                shards = shard_edges(np.asarray(graph.src),
                                     np.asarray(graph.dst), mesh)
            feed_wall_s = time.time() - t_feed
            _state, stats, size_g = run_distributed(
                None, None, v, cfg, mesh, shards=shards,
                checkpointer=ckp, monitor=monitor, resume=args.resume)
            fs = shards.stats
            result = {
                "dataset": args.edge_list or args.dataset, "V": v,
                "E": len(src),
                "mode": f"distributed{dict(mesh.shape)}",
                "size_bits": stats["size_bits"],
                "size_bits_before_sparsify": stats["size_bits_before"],
                "relative_size": stats["size_bits"] / size_g,
                "re1": stats["re1"], "re2": stats["re2"],
                "num_supernodes": stats["num_supernodes"],
                "num_superedges": stats["num_superedges"],
                "superedges_dropped": stats["dropped"],
                "sparsify_wall_s": stats["sparsify_wall_s"],
                "feed_wall_s": feed_wall_s,
                "feed_path": fs.path,
                "feed_shard_rows": fs.shard_rows,
                "feed_shard_bytes": fs.shard_bytes,
                "feed_peak_staging_bytes": fs.peak_staging_bytes,
                "feed_bytes_copied": fs.bytes_copied,
                "feed_local_shards": fs.local_shards,
                "process_count": dist.process_count,
                "process_index": dist.process_index,
                "chunk_wall_s": stats["chunk_wall_s"],
                "straggler_events": stats["straggler_events"],
                "resumed_from": stats["resumed_from"],
                "checkpoint_saves": stats["checkpoint_saves"],
                "checkpoint_snapshot_wall_s":
                    stats["checkpoint_snapshot_wall_s"],
                "wall_s": time.time() - t0,
            }
        else:
            res = summarize(src, dst, v, cfg, checkpointer=ckp,
                            monitor=monitor, resume=args.resume)
            result = {
                "dataset": args.edge_list or args.dataset, "V": v,
                "E": len(src),
                "mode": "local",
                "size_bits": res.size_bits,
                "relative_size": res.size_bits / res.input_size_bits,
                "re1": res.re1, "re2": res.re2,
                "num_supernodes": res.num_supernodes,
                "num_superedges": res.num_superedges,
                "iterations": res.iterations_run,
                "chunk_wall_s": res.chunk_wall_s,
                "straggler_events": [dataclasses.asdict(ev)
                                     for ev in res.straggler_events],
                "resumed_from": res.resumed_from,
                "checkpoint_saves": res.checkpoint_saves,
                "checkpoint_snapshot_wall_s":
                    res.checkpoint_snapshot_wall_s,
                "wall_s": time.time() - t0,
            }
    except Preempted as p:
        # save-and-exit: the committed checkpoint is the resume point;
        # RESUMABLE_EXIT tells the supervisor "rerun me with --resume"
        print(json.dumps(dict(
            ingest, preempted=True, checkpoint_step=p.step,
            checkpoint_dir=args.checkpoint_dir,
            wall_s=time.time() - t0), indent=1))
        raise SystemExit(RESUMABLE_EXIT)
    result.update(ingest)
    if ckp is not None:
        result["checkpoint_dir"] = args.checkpoint_dir
    result["peak_rss_mb"] = peak_rss_mb()
    print(json.dumps(result, indent=1))
    if (args.rss_budget_mb is not None and result["peak_rss_mb"] is not None
            and result["peak_rss_mb"] > args.rss_budget_mb):
        raise SystemExit(
            f"peak RSS {result['peak_rss_mb']:.1f} MB exceeds the "
            f"--rss-budget-mb {args.rss_budget_mb:.1f} MB gate")
    return result


if __name__ == "__main__":
    main()
