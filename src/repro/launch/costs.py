"""Roofline accounting: HLO costs + analytic scan corrections + collectives.

Three-term roofline per (arch × shape × mesh), v5e constants:

    compute    = FLOPs_corrected / (chips · 197e12)         [bf16]
    memory     = bytes_corrected / (chips · 819e9)
    collective = collective_bytes / (chips · 50e9)          [per-link ICI]

``cost_analysis`` counts every ``lax.scan`` body exactly once (measured in
DESIGN.md §8), so models are built with python-loop layers and the only
scans left are (a) blockwise-attention q/kv loops, (b) SSD / mLSTM chunk
loops, (c) the sLSTM time loop. Each has a closed-form FLOP count; the
correction adds ``true·(1 − 1/trips)`` so the reported compute term is
exact for matmul work (elementwise/softmax flops inside the scans are
neglected — they are ≤2% of the matmul flops at these shapes).

Collective bytes are parsed from the *partitioned* (per-device) HLO; op
factors approximate ring algorithms: all-reduce ×2, all-gather /
reduce-scatter / all-to-all ×1, collective-permute ×1.
"""

from __future__ import annotations

import re
from typing import Any

from repro.configs.base import ModelConfig, ShapeSpec

# ---- hardware constants (TPU v5e) -----------------------------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

# multipliers: forward=1; +2 backward; +1 remat-recompute
def _mult(mode: str, remat: bool) -> float:
    if mode == "train":
        return 4.0 if remat else 3.0
    return 1.0


# ---------------------------------------------------------------------------
# analytic scan corrections (per family)
# ---------------------------------------------------------------------------


def _attn_instance(b, s, t, heads, hd, mult, q_block=256, kv_block=1024):
    """(true, counted) matmul FLOPs of one blockwise-attention instance."""
    fwd = 4.0 * b * heads * s * t * hd
    true = fwd * mult
    nq = max(s // min(q_block, s), 1)
    nk = max(t // min(kv_block, t), 1)
    return true, true / (nq * nk)


def _ssd_instance(cfg: ModelConfig, b, s, mult):
    di = cfg.ssm_expand * cfg.d_model
    h = di // cfg.ssm_head_dim
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    nc = max(s // q, 1)
    fwd = 2.0 * b * s * (q * n + q * h * p + 2.0 * h * n * p + q * h)
    true = fwd * mult
    return true, true / nc


def _mlstm_instance(cfg: ModelConfig, b, s, mult, chunk=256):
    di = 2 * cfg.d_model
    h = cfg.n_heads
    p = di // h
    q = min(chunk, s)
    nc = max(s // q, 1)
    fwd = 2.0 * b * s * (3.0 * q * h * p + 3.0 * h * p * p)
    true = fwd * mult
    return true, true / nc


def _slstm_instance(cfg: ModelConfig, b, s, mult):
    dh = cfg.d_model // cfg.n_heads
    fwd = 8.0 * b * s * cfg.d_model * dh  # 4 recurrent matmuls
    true = fwd * mult
    return true, true / s


def flop_correction(cfg: ModelConfig, sp: ShapeSpec, remat: bool = True) -> float:
    """FLOPs to ADD to the HLO count (true − counted over all scan bodies)."""
    mode = sp.kind
    if mode == "decode":
        return 0.0  # decode paths are scan-free
    b, s = sp.global_batch, sp.seq_len
    mult = _mult("train" if mode == "train" else "prefill", remat)
    add = 0.0

    if cfg.family in ("dense", "moe", "vlm"):
        t_len = s
        true, counted = _attn_instance(b, s, t_len, cfg.n_heads, cfg.hd, mult)
        add += cfg.n_layers * (true - counted)
    elif cfg.family == "encdec":
        e = cfg.enc_len
        tr, ct = _attn_instance(b, e, e, cfg.n_heads, cfg.hd, mult)
        add += cfg.enc_layers * (tr - ct)  # encoder self
        tr, ct = _attn_instance(b, s, s, cfg.n_heads, cfg.hd, mult)
        add += cfg.n_layers * (tr - ct)  # decoder self
        tr, ct = _attn_instance(b, s, e, cfg.n_heads, cfg.hd, mult)
        add += cfg.n_layers * (tr - ct)  # cross
    elif cfg.family == "hybrid":
        tr, ct = _ssd_instance(cfg, b, s, mult)
        add += cfg.n_layers * (tr - ct)
        n_sites = cfg.n_layers // max(cfg.attn_every, 1)
        tr, ct = _attn_instance(b, s, s, cfg.n_heads, cfg.hd, mult)
        add += n_sites * (tr - ct)
    elif cfg.family == "xlstm":
        n_m = (cfg.n_layers + 1) // 2
        n_s = cfg.n_layers // 2
        tr, ct = _mlstm_instance(cfg, b, s, mult)
        add += n_m * (tr - ct)
        tr, ct = _slstm_instance(cfg, b, s, mult)
        add += n_s * (tr - ct)
    return add


def bytes_correction(cfg: ModelConfig, sp: ShapeSpec, remat: bool = True) -> float:
    """Approximate HBM-bytes to add for scan-hidden KV/chunk re-reads."""
    if sp.kind == "decode":
        return 0.0
    b, s = sp.global_batch, sp.seq_len
    mult = _mult("train" if sp.kind == "train" else "prefill", remat)
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        # blockwise attention re-reads K/V once per q-block
        nq = max(s // 256, 1)
        layers = cfg.n_layers if cfg.family != "encdec" else cfg.n_layers + cfg.enc_layers
        kv = 2.0 * s * cfg.n_kv_heads * cfg.hd * 2.0  # bytes, bf16
        return layers * b * nq * kv * mult
    return 0.0


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful work" yardstick)
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, sp: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if sp.kind == "train":
        return 6.0 * n_active * sp.global_batch * sp.seq_len
    if sp.kind == "prefill":
        return 2.0 * n_active * sp.global_batch * sp.seq_len
    return 2.0 * n_active * sp.global_batch  # decode: one token / sequence


# ---------------------------------------------------------------------------
# collective-bytes parser (partitioned HLO text)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\([^=]*?\)|[a-z0-9\[\],{}: ]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_OP_FACTOR = {
    "all-reduce": 2.0,  # ring: 2(n-1)/n ≈ 2
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device collective traffic by op kind (ring-algorithm factors)."""
    out: dict[str, float] = {k: 0.0 for k in _OP_FACTOR}
    out["total"] = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        b = _shape_bytes(shapes) * _OP_FACTOR[op]
        out[op] += b
        out["total"] += b
    return out


# ---------------------------------------------------------------------------
# roofline assembly
# ---------------------------------------------------------------------------


def roofline(
    *,
    hlo_flops_per_dev: float,
    hlo_bytes_per_dev: float,
    coll_bytes_per_dev: float,
    cfg: ModelConfig,
    sp: ShapeSpec,
    n_chips: int,
    remat: bool = True,
) -> dict[str, Any]:
    flops_total = hlo_flops_per_dev * n_chips + flop_correction(cfg, sp, remat)
    bytes_total = hlo_bytes_per_dev * n_chips + bytes_correction(cfg, sp, remat)
    t_compute = flops_total / (n_chips * PEAK_FLOPS)
    t_memory = bytes_total / (n_chips * HBM_BW)
    t_coll = coll_bytes_per_dev / ICI_BW  # per-device traffic on its links
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, sp)
    t_model = mf / (n_chips * PEAK_FLOPS)
    step_time = max(terms.values())
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_total": flops_total,
        "useful_ratio": mf / max(flops_total, 1.0),
        "roofline_fraction": t_model / max(step_time, 1e-12),
        "step_time_bound_s": step_time,
    }
