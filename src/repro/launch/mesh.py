"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (data, model) or 2 pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over host CPU devices (tests)."""
    return make_mesh(shape, axes)


def mesh_device_count(mesh) -> int:
    out = 1
    for v in mesh.shape.values():
        out *= v
    return out
