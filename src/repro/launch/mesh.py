"""Production meshes + the multi-host bootstrap. Defined as FUNCTIONS so
importing this module never touches jax device state (the dry-run sets
XLA_FLAGS before any jax import).

:func:`bootstrap_distributed` is the one place the tree calls
``jax.distributed.initialize`` (DESIGN.md §15): it must run before the
first device query of the process, it is a no-op for single-process runs
(every existing entry point keeps working unchanged), and on the CPU
backend it switches the collectives implementation to one that can cross
a process boundary. After it returns, ``jax.devices()`` spans every
process and the planned mesh is a real process-spanning mesh — the same
``shard_map`` programs run unchanged, with gloo carrying the collectives
between hosts.
"""

from __future__ import annotations

import dataclasses
import os

from repro.dist.compat import make_mesh

#: environment fallbacks for the bootstrap flags — one launch command can be
#: broadcast to every host with only these three variables differing.
COORDINATOR_ENV = "SSUMM_COORDINATOR"
NUM_PROCESSES_ENV = "SSUMM_NUM_PROCESSES"
PROCESS_ID_ENV = "SSUMM_PROCESS_ID"


@dataclasses.dataclass(frozen=True)
class DistributedInfo:
    """What :func:`bootstrap_distributed` resolved for this process."""

    initialized: bool
    coordinator: str | None
    process_count: int
    process_index: int

    @property
    def is_main(self) -> bool:
        return self.process_index == 0

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def _env_int(name: str) -> int | None:
    val = os.environ.get(name)
    return int(val) if val not in (None, "") else None


def bootstrap_distributed(coordinator: str | None = None,
                          num_processes: int | None = None,
                          process_id: int | None = None) -> DistributedInfo:
    """``jax.distributed.initialize`` with a single-process no-op fallback.

    Flag precedence: explicit arguments, then the ``SSUMM_COORDINATOR`` /
    ``SSUMM_NUM_PROCESSES`` / ``SSUMM_PROCESS_ID`` environment variables.
    With ``num_processes`` unset or 1 nothing is initialized and the run
    behaves exactly as before (local devices only). Otherwise all three
    values must resolve, and the call MUST happen before anything touches
    jax device state — ``jax.distributed.initialize`` cannot attach to an
    already-initialized backend.

    On the CPU backend the default collectives implementation cannot cross
    processes, so multi-process runs switch to gloo
    (``jax_cpu_collectives_implementation``) — measured bit-identical to
    the single-process reductions on the same global device count
    (tests/multihost_check.py). jax builds without that config knob simply
    skip it (their backends ship working cross-process collectives).
    """
    coordinator = coordinator or os.environ.get(COORDINATOR_ENV) or None
    if num_processes is None:
        num_processes = _env_int(NUM_PROCESSES_ENV)
    if process_id is None:
        process_id = _env_int(PROCESS_ID_ENV)
    if num_processes is None or num_processes <= 1:
        return DistributedInfo(initialized=False, coordinator=None,
                               process_count=1, process_index=0)
    if coordinator is None or process_id is None:
        raise ValueError(
            f"multi-process bootstrap needs --coordinator and --process-id "
            f"(or ${COORDINATOR_ENV}/${PROCESS_ID_ENV}) alongside "
            f"num_processes={num_processes}")

    import jax

    try:  # CPU: cross-process collectives need gloo (no-op elsewhere)
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:  # jax build without the knob
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=int(num_processes),
                               process_id=int(process_id))
    return DistributedInfo(initialized=True, coordinator=coordinator,
                           process_count=int(num_processes),
                           process_index=int(process_id))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (data, model) or 2 pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over host CPU devices (tests)."""
    return make_mesh(shape, axes)


def mesh_device_count(mesh) -> int:
    out = 1
    for v in mesh.shape.values():
        out *= v
    return out
