"""End-to-end training driver (deliverable b): data pipeline → sharded
train loop → checkpoint/restart → straggler + preemption handling.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm_350m --smoke \
        --steps 200 --batch 8 --seq 128

Production features exercised here on any device count (CPU included):
  * mesh planned from the live device count (elastic restart: relaunch with
    fewer devices and the same global batch — plan_mesh rescales),
  * FSDP/TP shardings from the same rule table as the dry-run,
  * gradient accumulation (``--accum``), optional gradient compression,
  * atomic keep-N checkpoints with async writes; ``--resume`` restores the
    latest commit (reshard-on-restore under the *current* mesh),
  * straggler monitor + SIGTERM-safe preemption checkpoint.

XLA latency-hiding flags (collective/compute overlap on TPU) are set before
the jax import; they are harmless no-ops on CPU.
"""

import os

os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true",
)

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import RunConfig, get_config, get_smoke_config
from repro.data import SyntheticTokens, TokenDatasetConfig
from repro.dist import CompressConfig, microbatch_grads
from repro.dist.sharding import make_rules
from repro.launch.lowering import _tree_shardings
from repro.models.api import build_model
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.runtime import (
    CheckpointManager,
    PreemptionGuard,
    StragglerMonitor,
    make_mesh_from_plan,
    plan_mesh,
)


def build_train_step(model, rules, run: RunConfig, accum: int, mesh=None):
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map
    from repro.dist.compress import compressed_allreduce

    def loss_fn(p, b):
        return model.loss(p, b, rules, remat=run.remat)

    compress = run.grad_compress
    if compress != "none":
        if mesh is None:
            raise ValueError(
                "grad compression needs the mesh: the codecs run inside a "
                "shard_map'd all-reduce (pass mesh= to build_train_step)")
        ccfg = CompressConfig(compress, topk_ratio=run.topk_ratio)
        axis_names = tuple(mesh.axis_names)
        n_dev = rules.n_devices

        def wire_allreduce(grads, err):
            # Each device contributes grads/n_dev; summing the decoded
            # contributions reconstructs the compressed gradient while the
            # int8 / top-k payload actually crosses the wire — and, on a
            # process-spanning mesh, the process boundary (DESIGN.md §15).
            # For power-of-two device counts the reconstruction is bitwise
            # the old inline quantize→dequantize transform.
            def body(g, e):
                contrib = jax.tree.map(lambda x: x / n_dev, g)
                return compressed_allreduce(contrib, e, ccfg, axis_names)

            return shard_map(body, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P(), P()),
                             check_vma=False)(grads, err)

    def step_fn(params, opt, batch, err):
        loss, _aux, grads = microbatch_grads(loss_fn, params, batch, accum)
        wire_bytes = 0.0
        if compress != "none":
            grads, err, wire_bytes = wire_allreduce(grads, err)
        lr = cosine_schedule(opt.step + 1, base_lr=run.lr,
                             warmup=run.warmup_steps, total=run.total_steps,
                             min_ratio=run.lr_min_ratio)
        params, opt, om = adamw_update(
            grads, opt, params, lr=lr, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip,
        )
        return params, opt, err, {"loss": loss, "wire_bytes": wire_bytes,
                                  **om}

    return step_fn


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm_350m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", choices=("none", "topk", "int8"), default="none")
    ap.add_argument("--want-model", type=int, default=1, help="TP degree cap")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 10, 1),
                    checkpoint_every=args.ckpt_every, grad_compress=args.compress)

    # ---- mesh from the live device count (elastic) -----------------------
    n_dev = jax.device_count()
    plan = plan_mesh(n_dev, global_batch=args.batch, want_model=args.want_model)
    mesh = make_mesh_from_plan(plan)
    rules = make_rules(mesh, "train")
    print(f"devices={n_dev} mesh={dict(mesh.shape)} "
          f"per_device_batch={plan.per_device_batch} accum={plan.accum_steps}")

    # ---- model + sharded init -------------------------------------------
    model = build_model(cfg)
    axes = model.axes()
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(args.seed))
    p_shard = _tree_shardings(rules, params_s, axes)
    with mesh:
        params = jax.jit(model.init, out_shardings=p_shard)(
            jax.random.PRNGKey(args.seed)
        )
        opt = adamw_init(params)

    # ---- data -------------------------------------------------------------
    ds = SyntheticTokens(TokenDatasetConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))
    b_shard = rules.sharding(("batch", "seq"), (args.batch, args.seq))

    accum = max(args.accum, plan.accum_steps)
    step_fn = build_train_step(model, rules, run, accum, mesh)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 3))
    from repro.dist.compress import init_error_buffers, payload_bytes

    err = init_error_buffers(params) if args.compress == "topk" else None
    ccfg = CompressConfig(args.compress, topk_ratio=run.topk_ratio)
    if args.compress != "none":
        full = payload_bytes(params, CompressConfig("none"))
        wire = payload_bytes(params, ccfg)
        print(f"grad compression {args.compress}: {full/2**20:.1f} MiB "
              f"-> {wire/2**20:.1f} MiB per all-reduce payload "
              f"(asserted against the measured wire counter)")

    # ---- fault tolerance ---------------------------------------------------
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=run.keep_checkpoints)
        if args.resume and ckpt.latest_step() is not None:
            (params, opt), start_step, _ = ckpt.restore(
                (params, opt),
                sharding_fn=None,  # device_put default; resharded below
            )
            with mesh:
                params = jax.device_put(params, p_shard)
            print(f"resumed from step {start_step}")
    guard = PreemptionGuard()
    monitor = StragglerMonitor()
    monitor.on_straggler(
        lambda ev: print(f"  [straggler] step {ev.step}: "
                         f"{ev.step_time:.2f}s = {ev.ratio:.1f}× mean")
    )

    # ---- loop --------------------------------------------------------------
    losses = []
    wire_per_step = None
    t_begin = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            monitor.begin_step()
            batch = {"tokens": jax.device_put(ds.batch(step), b_shard)}
            params, opt, err, metrics = jit_step(params, opt, batch, err)
            loss = float(metrics["loss"])
            wire_per_step = float(metrics["wire_bytes"])
            losses.append(loss)
            monitor.end_step(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if ckpt and ((step + 1) % run.checkpoint_every == 0):
                ckpt.save_async(step + 1, (params, opt))
            if guard.preempted:
                print("preemption signal: saving + exiting")
                if ckpt:
                    ckpt.save(step + 1, (params, opt))
                break
    if ckpt:
        ckpt.wait()
        ckpt.save(args.steps, (params, opt))
    wall = time.time() - t_begin
    result = {
        "arch": cfg.name, "steps": len(losses), "wall_s": wall,
        "loss_first": losses[0] if losses else None,
        "loss_last": losses[-1] if losses else None,
        "stragglers": len(monitor.events),
    }
    if args.compress != "none" and losses:
        # wire accounting: what the collective measured (psum'd counter
        # from the actual wire-array shapes) must equal what
        # payload_bytes priced — per device, times every device
        expected = n_dev * payload_bytes(params, ccfg)
        if not np.isclose(wire_per_step, expected, rtol=1e-6):
            raise AssertionError(
                f"wire accounting drift: measured {wire_per_step:.0f} B "
                f"per step, payload_bytes prices {expected:.0f} B")
        result["wire_bytes_per_step"] = wire_per_step
        result["wire_bytes_expected"] = expected
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
