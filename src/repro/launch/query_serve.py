"""Batched summary-query serving driver (DESIGN.md §14).

    PYTHONPATH=src python -m repro.launch.query_serve --dataset dblp \
        --scale 0.05 --k-frac 0.3 --T 10 --requests 512 --batch 64

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.query_serve --edge-list g.txt.gz \
        --distributed --requests 256 --batch 64

Summarizes the graph (or loads it through the same registry/CSR-cache
resolution as ``launch.summarize``), builds the device-resident
:class:`repro.core.queries_jax.QueryEngine` (``--distributed``: the
owner-routed :class:`RoutedQueryEngine` over every local device, or with
``--tier partitioned`` the memory-partitioned
:class:`PartitionedQueryEngine`), and serves a mixed analytics workload —
expected degree, adjacency weight, PageRank, triangle density, k-hop
neighborhood size, cut weight, conductance — through the same static-slot
scheduler idiom as ``launch.serve``: requests pack into a fixed
``--batch``-wide slot vector (static shapes ⇒ one compilation), mixed
query types route per-slot through one fused dispatch, and finished slots
refill from the queue each step. The JSON reports p50/p99 per-request
latency, QPS, and an order-independent sha256 digest of the answers — the
CI partitioned smoke compares it against the replicated tier's digest for
cross-process bit-identity.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import time

import jax
import numpy as np

from repro.core import SummaryConfig, summarize
from repro.core.queries_jax import (
    _SET_KINDS,
    KIND_CONDUCTANCE,
    KIND_CUT,
    KIND_KHOP,
    KIND_NAMES,
    PartitionedQueryEngine,
    QueryEngine,
    RoutedQueryEngine,
    pack_set_counts,
)
from repro.graphs import DATASETS, load_graph
from repro.runtime import make_mesh_from_plan, plan_mesh


@dataclasses.dataclass
class QueryRequest:
    rid: int
    kind: int       # KIND_* (repro.core.queries_jax)
    u: int = 0      # target node (degree/pagerank; row side of adjacency)
    v: int = 0      # second node (adjacency); hop count k (khop)
    a: np.ndarray | None = None  # node set A (cut/conductance)
    b: np.ndarray | None = None  # node set B (cut)
    answer: float | None = None
    t_submit: float = 0.0
    t_done: float = 0.0


class QueryServer:
    """Fixed-slot batch scheduler over a query engine.

    Queries are single-shot, so the continuous-batching loop degenerates
    nicely: every step admits up to ``slots`` requests from the queue into
    the fixed-shape slot vectors, answers them in one fused jitted
    dispatch, and frees every slot for the next step. Idle slots are
    padded with a degree probe of node 0 and masked out — the padded batch
    keeps the compiled shape, so a ragged final batch costs no
    recompilation (and, because slots are independent lanes of a
    vectorized kernel, answers cannot depend on batch packing —
    tests/test_query_serving.py pins this).
    """

    def __init__(self, engine, *, slots: int):
        self.engine = engine
        self.slots = slots
        self.queue: list[QueryRequest] = []
        self.done: list[QueryRequest] = []

    def submit(self, req: QueryRequest) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def step(self) -> bool:
        """Serve one batch. Returns False when the queue is drained."""
        if not self.queue:
            return False
        batch = [self.queue.pop(0) for _ in range(min(self.slots,
                                                      len(self.queue)))]
        kinds = np.zeros(self.slots, np.int32)
        u = np.zeros(self.slots, np.int32)
        v = np.zeros(self.slots, np.int32)
        for s, req in enumerate(batch):
            kinds[s], u[s], v[s] = req.kind, req.u, req.v
        if np.isin(kinds, _SET_KINDS).any():
            sets_a = [None] * self.slots
            sets_b = [None] * self.slots
            for s, req in enumerate(batch):
                sets_a[s], sets_b[s] = req.a, req.b
            ca, cb, ov = pack_set_counts(self.engine.bs, kinds,
                                         sets_a, sets_b)
            answers = self.engine.answer_batch(kinds, u, v, ca, cb, ov)
        else:
            answers = self.engine.answer_batch(kinds, u, v)
        t = time.perf_counter()
        for s, req in enumerate(batch):
            req.answer = float(answers[s])
            req.t_done = t
            self.done.append(req)
        return True


def random_workload(rng, v: int, n: int, kinds: list[int],
                    max_set: int | None = None,
                    k_max: int = 4) -> list[QueryRequest]:
    """A uniform mixed-kind request stream over random target nodes.

    Set kinds (cut/conductance) draw random node sets of up to
    ``max_set`` nodes (default v//4, at least 1); khop draws k in
    [0, ``k_max``] carried in the v lane."""
    max_set = max(1, v // 4) if max_set is None else max_set
    out = []
    for rid in range(n):
        kind = kinds[rid % len(kinds)]
        req = QueryRequest(rid=rid, kind=kind,
                           u=int(rng.integers(0, v)),
                           v=int(rng.integers(0, v)))
        if kind == KIND_KHOP:
            req.v = int(rng.integers(0, k_max + 1))
        elif kind in _SET_KINDS:
            req.a = rng.choice(v, size=int(rng.integers(1, max_set + 1)),
                               replace=False)
            if kind == KIND_CUT:
                req.b = rng.choice(
                    v, size=int(rng.integers(1, max_set + 1)),
                    replace=False)
        out.append(req)
    return out


def answers_digest(done: list[QueryRequest]) -> str:
    """Order-independent sha256 over (rid, float64 answer) pairs — equal
    digests ⇒ bit-identical answers for the same workload."""
    by_rid = sorted((r.rid, r.answer) for r in done)
    buf = np.array([[float(rid), float(ans)] for rid, ans in by_rid],
                   np.float64)
    return hashlib.sha256(buf.tobytes()).hexdigest()


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--edge-list", default=None, metavar="PATH",
                    help="SNAP edge-list file; overrides --dataset/--scale")
    ap.add_argument("--chunk-edges", type=int, default=None)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k-frac", type=float, default=0.3)
    ap.add_argument("--T", type=int, default=10)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64,
                    help="slot count of the static-batch scheduler")
    ap.add_argument("--queries", default="degree,adjacency,pagerank",
                    help="comma-separated kinds to mix "
                         f"(of {sorted(KIND_NAMES)}); triangle is opt-in — "
                         "it is the one summary-space query that is not "
                         "O(1) per probe on large summaries")
    ap.add_argument("--distributed", action="store_true",
                    help="owner-routed engine over all local devices")
    ap.add_argument("--tier", default="replicated",
                    choices=("replicated", "partitioned"),
                    help="--distributed storage tier: replicated rows "
                         "(RoutedQueryEngine) or device-sharded rows with "
                         "halo exchange (PartitionedQueryEngine)")
    ap.add_argument("--dense-row-nnz", type=int, default=None,
                    help="partitioned tier: rows denser than this leave "
                         "the resident halo and use the second-hop route")
    ap.add_argument("--pagerank-iters", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kind_names = [k.strip() for k in args.queries.split(",") if k.strip()]
    unknown = [k for k in kind_names if k not in KIND_NAMES]
    if unknown:
        ap.error(f"unknown query kind(s) {unknown}; "
                 f"expected from {sorted(KIND_NAMES)}")
    kinds = [KIND_NAMES[k] for k in kind_names]

    g = load_graph(args.edge_list or args.dataset,
                   chunk_edges=args.chunk_edges, scale=args.scale,
                   seed=args.seed)
    src, dst, v = np.asarray(g.src), np.asarray(g.dst), g.num_nodes
    cfg = SummaryConfig(T=args.T, k_frac=args.k_frac,
                        group_size=args.group_size, seed=args.seed)
    t0 = time.time()
    res = summarize(src, dst, v, cfg, collect_history=False)
    summarize_wall_s = time.time() - t0

    t0 = time.time()
    partition_stats = None
    if args.distributed:
        plan = plan_mesh(jax.device_count(), global_batch=1, want_model=1)
        mesh = make_mesh_from_plan(plan)
        if args.tier == "partitioned":
            engine = PartitionedQueryEngine(
                res, mesh, pagerank_iters=args.pagerank_iters,
                dense_row_nnz=args.dense_row_nnz)
            mode = f"partitioned{dict(mesh.shape)}"
            partition_stats = engine.partition_stats()
        else:
            engine = RoutedQueryEngine(res, mesh,
                                       pagerank_iters=args.pagerank_iters)
            mode = f"routed{dict(mesh.shape)}"
        owner_counts = engine.owner_counts().tolist()
    else:
        engine = QueryEngine(res, pagerank_iters=args.pagerank_iters)
        mode = "local"
        owner_counts = None
    build_wall_s = time.time() - t0

    rng = np.random.default_rng(args.seed)
    server = QueryServer(engine, slots=args.batch)
    # warmup: compile the fused dispatch (and any lazy global queries the
    # workload needs) outside the timed window
    warm = random_workload(rng, v, args.batch, kinds)
    for req in warm:
        server.submit(req)
    while server.step():
        pass
    server.done.clear()

    reqs = random_workload(rng, v, args.requests, kinds)
    t0 = time.perf_counter()
    for req in reqs:
        server.submit(req)
    while server.step():
        pass
    wall = time.perf_counter() - t0

    lat = np.array([r.t_done - r.t_submit for r in server.done])
    per_kind = {name: int(sum(r.kind == k for r in server.done))
                for name, k in KIND_NAMES.items() if k in kinds}
    result = {
        "dataset": args.edge_list or args.dataset,
        "V": v, "E": len(src),
        "num_supernodes": res.num_supernodes,
        "num_superedges": res.num_superedges,
        "mode": mode,
        "batch": args.batch,
        "requests": len(server.done),
        "queries": per_kind,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "qps": len(server.done) / max(wall, 1e-9),
        "wall_s": wall,
        "summarize_wall_s": summarize_wall_s,
        "engine_build_wall_s": build_wall_s,
        "answers_digest": answers_digest(server.done),
        "source": g.source,
    }
    if owner_counts is not None:
        result["owner_counts"] = owner_counts
    if partition_stats is not None:
        result["partition_stats"] = partition_stats
    print(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    main()
