"""Batched serving driver: prefill + decode with a static-batch scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_14b --smoke \
        --requests 8 --prompt-len 32 --gen-len 32

Serving path of the same Model API the dry-run lowers (`prefill_step` /
`serve_step`); the scheduler packs requests into fixed slots (static shapes
⇒ one compilation), tracks per-slot positions, refills finished slots from
the queue (continuous batching), and samples greedily. TP/flash-decoding
shardings come from the same `make_rules(mesh, "serve")` table as the
dry-run cells.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.sharding import make_rules
from repro.models.api import build_model
from repro.runtime import make_mesh_from_plan, plan_mesh


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 [L]
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class BatchServer:
    """Fixed-slot continuous-batching server over the Model API.

    Slots advance **independently** (per-slot decode positions — the decode
    paths accept an int32[B] position vector), so a request can be admitted
    into a free slot mid-flight without synchronizing the other slots:
    during admission the new slot teacher-forces its prompt while occupied
    slots keep their frozen position (their cache line is rewritten by their
    own next real token, so no state leaks between requests)."""

    def __init__(self, cfg, *, slots: int, max_len: int, rules=None, seed=0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.rules = rules
        self.slots = slots
        self.max_len = max_len
        self.params = self.model.init(jax.random.PRNGKey(seed))
        # de-alias: XLA may dedupe identical zero buffers across cache
        # leaves, which breaks donation (same buffer donated twice)
        self.cache = jax.tree.map(
            lambda x: jnp.array(x, copy=True), self.model.init_cache(slots, max_len)
        )
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.done: list[Request] = []

        def decode(params, cache, token, pos):
            return self.model.serve_step(
                params, {"token": token, "pos": pos, "cache": cache}, rules
            )

        self._decode = jax.jit(decode, donate_argnums=(1,))

        # slot-masked cache restore: keep `new` where mask else `old`
        # (recurrent families update state irreversibly — admissions must
        # not advance other slots' SSM/mLSTM states)
        def restore(new, old, mask):
            def one(n, o):
                m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
                return jnp.where(m, n, o)

            return jax.tree.map(one, new, old)

        self._restore = jax.jit(restore)

        # zero one slot's cache/state at admission: KV caches are protected
        # by position masking, but recurrent (SSM/mLSTM) state would leak
        # the previous request into the next one
        def clear(cache, mask):
            def one(x):
                m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.where(m, jnp.zeros_like(x), x)

            return jax.tree.map(one, cache)

        self._clear = jax.jit(clear)

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _run(self, token: np.ndarray, pos: np.ndarray):
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(token, jnp.int32),
            jnp.asarray(np.minimum(pos, self.max_len - 1), jnp.int32),
        )
        return np.asarray(logits)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                # teacher-force the prompt through the decode path at this
                # slot's own positions. KV caches of other slots are safe by
                # masking (their frozen position is rewritten by their own
                # next token); recurrent state is NOT — snapshot and restore
                # every slot except s afterwards.
                mask = jnp.asarray(np.arange(self.slots) == s)
                self.cache = self._clear(self.cache, mask)
                snap = jax.tree.map(jnp.copy, self.cache)
                for i, tok in enumerate(req.prompt):
                    token = np.zeros(self.slots, np.int32)
                    token[s] = tok
                    pos = self.pos.copy()
                    pos[s] = i
                    logits = self._run(token, pos)
                self.cache = self._restore(self.cache, snap, mask)
                self.pos[s] = len(req.prompt)
                req.out.append(int(logits[s].argmax()))
                req.t_first = time.perf_counter()

    def step(self) -> bool:
        """One decode step for every active slot. Returns False when idle."""
        self._admit()
        if all(a is None for a in self.active):
            return False
        token = np.zeros(self.slots, np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                token[s] = req.out[-1]
        logits = self._run(token, self.pos)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(logits[s].argmax()))
            self.pos[s] += 1
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.active[s] = None
                self.pos[s] = 0  # slot reset for the next admission
        return True


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2_5_14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan = plan_mesh(jax.device_count(), global_batch=args.slots, want_model=1)
    mesh = make_mesh_from_plan(plan)
    rules = make_rules(mesh, "serve")

    rng = np.random.default_rng(args.seed)
    server = BatchServer(cfg, slots=args.slots, max_len=args.max_len,
                         rules=rules, seed=args.seed)
    with mesh:
        for rid in range(args.requests):
            server.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new=args.gen_len,
            ))
        t0 = time.perf_counter()
        while server.step():
            pass
    wall = time.perf_counter() - t0
    lat = [r.t_done - r.t_submit for r in server.done]
    ttft = [r.t_first - r.t_submit for r in server.done]
    toks = sum(len(r.out) for r in server.done)
    result = {
        "arch": cfg.name, "requests": len(server.done),
        "tokens": toks, "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "p50_latency_s": float(np.median(lat)) if lat else None,
        "p50_ttft_s": float(np.median(ttft)) if ttft else None,
    }
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
