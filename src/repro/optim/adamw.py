"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Built from scratch (no optax in the container). Moments are f32 regardless
of parameter dtype; the update is computed in f32 and cast back — the usual
mixed-precision training recipe. Optimizer state shards exactly like the
parameters (the state trees mirror the param tree, so the same NamedSharding
trees apply — see launch/train.py).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any  # f32 tree
    nu: Any  # f32 tree


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step_f = step.astype(jnp.float32)
    warm = base_lr * step_f / jnp.maximum(warmup, 1)
    prog = jnp.clip((step_f - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step_f < warmup, warm, cos)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.where(
        (grad_clip > 0) & (gnorm > grad_clip), grad_clip / jnp.maximum(gnorm, 1e-9), 1.0
    )
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), {
        "grad_norm": gnorm,
        "lr": lr,
    }
