"""repro — SSumM (KDD 2020) sparse graph summarization + distributed LM substrate."""

__version__ = "1.0.0"
