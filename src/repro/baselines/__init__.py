from repro.baselines.common import BaselineResult, evaluate_partition
from repro.baselines.kgs import summarize_kgs
from repro.baselines.s2l import summarize_s2l
from repro.baselines.saa_gs import summarize_saa_gs

__all__ = [
    "BaselineResult",
    "evaluate_partition",
    "summarize_kgs",
    "summarize_s2l",
    "summarize_saa_gs",
]
