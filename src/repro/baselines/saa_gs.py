"""SAA-Gs (Beg, Ahmad, Zaman & Khan, PAKDD'18): scalable approximation
algorithm for graph summarization.

Agglomeration toward a target supernode count with two accelerations from
the paper: (a) *weighted pair sampling* — candidate pairs are drawn with
probability proportional to supernode degree-weights kept in a sampling
tree (here: alias-free cumulative-weight binary search, re-built lazily);
(b) *count-min sketch* approximation of supernode adjacency — merge scores
use the sketch (w=50, d=2, the paper's setting) instead of exact neighbor
maps, trading accuracy for memory, which is exactly the quality gap Fig. 4/5
shows against SSumM. Two sampling budgets reproduce the paper's variants:
``log n`` (SAA-Gs) and ``n`` (linear-sample).
"""

from __future__ import annotations

import time

import numpy as np

from repro.baselines.common import BaselineResult, evaluate_partition


class CountMinSketch:
    """d independent rows of width w; conservative point updates."""

    def __init__(self, w: int = 50, d: int = 2, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.w, self.d = w, d
        self.salt = rng.integers(1, 2**31 - 1, size=d).astype(np.int64)
        self.table = np.zeros((d, w), dtype=np.float64)

    def _rows(self, key: int) -> np.ndarray:
        return (key * self.salt + (self.salt >> 3)) % self.w

    def add(self, key: int, val: float) -> None:
        self.table[np.arange(self.d), self._rows(key)] += val

    def query(self, key: int) -> float:
        return float(self.table[np.arange(self.d), self._rows(key)].min())


class SAAGs:
    def __init__(self, src, dst, num_nodes: int, *, w: int = 50, d: int = 2,
                 seed: int = 0):
        self.v = num_nodes
        self.src = np.asarray(src)
        self.dst = np.asarray(dst)
        self.rng = np.random.default_rng(seed)
        self.size = np.ones(num_nodes, dtype=np.int64)
        self.n2s = np.arange(num_nodes, dtype=np.int64)
        self.members: list[list[int]] = [[i] for i in range(num_nodes)]
        self.deg = np.zeros(num_nodes, dtype=np.float64)
        np.add.at(self.deg, self.src, 1.0)
        np.add.at(self.deg, self.dst, 1.0)
        # per-supernode count-min sketch of its adjacency counts
        self.sketch: list[CountMinSketch] = [
            CountMinSketch(w, d, seed + i) for i in range(num_nodes)
        ]
        for a, b in zip(self.src, self.dst):
            self.sketch[int(a)].add(int(b), 1.0)
            self.sketch[int(b)].add(int(a), 1.0)
        # exact neighbor id sets (ids only; counts live in the sketches)
        self.nbrs: list[set] = [set() for _ in range(num_nodes)]
        for a, b in zip(self.src, self.dst):
            self.nbrs[int(a)].add(int(b))
            self.nbrs[int(b)].add(int(a))

    # ---- weighted sampling over alive supernodes -------------------------
    def _sample_pairs(self, alive: np.ndarray, n: int) -> np.ndarray:
        w = self.deg[alive] + 1.0
        p = w / w.sum()
        i = self.rng.choice(alive.size, size=n, p=p)
        j = self.rng.choice(alive.size, size=n, p=p)
        return np.stack([alive[i], alive[j]], axis=1)

    # ---- sketch-approximate merge score -----------------------------------
    def _pi(self, a: int, b: int) -> float:
        if a == b:
            nn = float(self.size[a])
            return nn * (nn - 1) / 2
        return float(self.size[a]) * float(self.size[b])

    def _pair_err(self, cnt: float, pi: float) -> float:
        if pi <= 0:
            return 0.0
        cnt = min(cnt, pi)
        return 2.0 * cnt * (1.0 - cnt / pi)

    def score(self, a: int, b: int) -> float:
        """Approximate ΔRE₁ of merging (negative = improvement)."""
        nn = float(self.size[a] + self.size[b])
        w_ab = self.sketch[a].query(b) if b in self.nbrs[a] else 0.0
        before = after = 0.0
        before += self._pair_err(w_ab, self._pi(a, b))
        nbrs = (self.nbrs[a] | self.nbrs[b]) - {a, b}
        for c in nbrs:
            ca = self.sketch[a].query(c) if c in self.nbrs[a] else 0.0
            cb = self.sketch[b].query(c) if c in self.nbrs[b] else 0.0
            before += self._pair_err(ca, float(self.size[a]) * self.size[c])
            before += self._pair_err(cb, float(self.size[b]) * self.size[c])
            after += self._pair_err(ca + cb, nn * float(self.size[c]))
        return after - before

    def merge(self, a: int, b: int) -> None:
        if a > b:
            a, b = b, a
        self.sketch[a].table += self.sketch[b].table
        self.nbrs[a] |= self.nbrs[b]
        self.nbrs[a].discard(a)
        self.nbrs[a].discard(b)
        for c in self.nbrs[b]:
            if c != a:
                self.nbrs[c].discard(b)
                self.nbrs[c].add(a)
        self.nbrs[b] = set()
        self.members[a].extend(self.members[b])
        for u in self.members[b]:
            self.n2s[u] = a
        self.members[b] = []
        self.deg[a] += self.deg[b]
        self.deg[b] = 0.0
        self.size[a] += self.size[b]
        self.size[b] = 0

    def run(self, target_supernodes: int, linear_sample: bool = False
            ) -> BaselineResult:
        t0 = time.perf_counter()
        alive = np.flatnonzero(self.size > 0)
        while alive.size > max(target_supernodes, 2):
            n = alive.size if linear_sample else max(
                int(np.log2(max(alive.size, 2))), 1
            )
            pairs = self._sample_pairs(alive, n)
            best, best_pair = np.inf, None
            for a, b in pairs:
                a, b = int(a), int(b)
                if a == b:
                    continue
                s = self.score(a, b)
                if s < best:
                    best, best_pair = s, (a, b)
            if best_pair is None:
                continue
            self.merge(*best_pair)
            alive = np.flatnonzero(self.size > 0)
        name = "saa_gs_linear" if linear_sample else "saa_gs"
        res = evaluate_partition(self.src, self.dst, self.v, self.n2s, name)
        res.wall_s = time.perf_counter() - t0
        return res


def summarize_saa_gs(src, dst, num_nodes: int, target_frac: float = 0.3,
                     linear_sample: bool = False, seed: int = 0,
                     w: int = 50, d: int = 2) -> BaselineResult:
    return SAAGs(src, dst, num_nodes, w=w, d=d, seed=seed).run(
        max(int(target_frac * num_nodes), 2), linear_sample=linear_sample
    )
