"""S2L (Riondato, García-Soriano & Bonchi, DMKD'17): summarization via
geometric clustering of adjacency rows.

Each node is its adjacency row in R^|V|; clustering rows with k-means gives
supernodes with an ℓ_p reconstruction guarantee. As in the paper we avoid
the |V|-dimensional distance computations with a random-projection sketch
(Indyk-style dimensionality reduction to d = O(log|V|) dims, built directly
from the edge list in O(|E|·d)), then run k-means++ seeding + Lloyd in JAX
(one jit'd vectorized assignment/update per iteration — this baseline's
clustering is the only genuinely TPU-shaped competitor computation).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import BaselineResult, evaluate_partition


def project_rows(src, dst, num_nodes: int, dims: int, seed: int = 0):
    """Random projection of adjacency rows: P[u] = Σ_{v∈N(u)} R[v]."""
    rng = np.random.default_rng(seed)
    r = rng.standard_normal((num_nodes, dims)).astype(np.float32)
    r /= np.sqrt(dims)
    p = np.zeros((num_nodes, dims), np.float32)
    np.add.at(p, np.asarray(src), r[np.asarray(dst)])
    np.add.at(p, np.asarray(dst), r[np.asarray(src)])
    return p


@jax.jit
def _assign(x, centers):
    d = (
        jnp.sum(x * x, axis=1, keepdims=True)
        - 2.0 * x @ centers.T
        + jnp.sum(centers * centers, axis=1)[None, :]
    )
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def _update(x, assign, k):
    sums = jax.ops.segment_sum(x, assign, num_segments=k)
    cnts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), assign,
                               num_segments=k)
    return sums / jnp.maximum(cnts, 1.0)[:, None], cnts


def kmeans(x: np.ndarray, k: int, iters: int = 25, seed: int = 0):
    """k-means++ seeding (sampled) + jit'd Lloyd iterations."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    xd = jnp.asarray(x)
    # k-means++ on a subsample (adaptive sampling per the S2L paper)
    m = min(n, max(4 * k, 1024))
    sub = xd[rng.choice(n, size=m, replace=False)]
    centers = [sub[rng.integers(0, m)]]
    d2 = jnp.sum((sub - centers[0]) ** 2, axis=1)
    for _ in range(1, k):
        probs = np.asarray(d2, np.float64)
        tot = probs.sum()
        if tot <= 0:
            centers.append(sub[rng.integers(0, m)])
            continue
        i = rng.choice(m, p=probs / tot)
        centers.append(sub[i])
        d2 = jnp.minimum(d2, jnp.sum((sub - centers[-1]) ** 2, axis=1))
    c = jnp.stack(centers)
    assign = _assign(xd, c)
    for _ in range(iters):
        c, cnts = _update(xd, assign, k)
        # re-seed empty clusters at random points
        empty = np.flatnonzero(np.asarray(cnts) == 0)
        if empty.size:
            c = c.at[jnp.asarray(empty)].set(xd[rng.integers(0, n, empty.size)])
        new_assign = _assign(xd, c)
        if bool(jnp.all(new_assign == assign)):
            break
        assign = new_assign
    return np.asarray(assign)


def summarize_s2l(src, dst, num_nodes: int, target_frac: float = 0.3,
                  dims: int | None = None, iters: int = 25,
                  seed: int = 0) -> BaselineResult:
    t0 = time.perf_counter()
    k = max(int(target_frac * num_nodes), 2)
    dims = dims or max(int(np.ceil(np.log2(max(num_nodes, 2)))) * 2, 8)
    x = project_rows(src, dst, num_nodes, dims, seed)
    assign = kmeans(x, k, iters=iters, seed=seed)
    res = evaluate_partition(src, dst, num_nodes, assign, "s2l")
    res.wall_s = time.perf_counter() - t0
    return res
