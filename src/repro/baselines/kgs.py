"""k-Gs (GraSS, LeFevre & Terzi SDM'10) with the SamplePairs strategy.

Greedy agglomeration toward a target supernode count: at every step sample
``c·|S|`` candidate pairs (c = 1.0, as the paper's suggested setting),
merge the pair with the largest ℓ1-error *reduction* (equivalently the
smallest increase). All nonzero superedges are kept — k-Gs never sparsifies,
which is exactly the behavior Fig. 4 contrasts SSumM against.

The ℓ1 closed form per supernode pair (cnt, Π): 2·cnt·(1−cnt/Π); a merge's
ΔRE₁ touches only pairs adjacent to A or B, evaluated exactly over the
union of their neighbor maps (numpy/dict machinery — the baseline is
sequential by construction; its O(T·|V|·deg) cost is the paper's point
about scalability, reproduced in benchmarks/fig5_speed.py).
"""

from __future__ import annotations

import time

import numpy as np

from repro.baselines.common import BaselineResult, adjacency_dicts, evaluate_partition


def _pair_err(cnt: float, pi: float) -> float:
    if pi <= 0:
        return 0.0
    return 2.0 * cnt * (1.0 - cnt / pi)


class KGs:
    def __init__(self, src, dst, num_nodes: int, seed: int = 0):
        self.v = num_nodes
        self.src = np.asarray(src)
        self.dst = np.asarray(dst)
        self.adj = adjacency_dicts(src, dst, num_nodes)
        self.selfc = np.zeros(num_nodes, dtype=np.float64)
        self.size = np.ones(num_nodes, dtype=np.int64)
        self.n2s = np.arange(num_nodes, dtype=np.int64)
        self.members: list[list[int]] = [[i] for i in range(num_nodes)]
        self.rng = np.random.default_rng(seed)

    def _pi(self, a: int, b: int) -> float:
        if a == b:
            n = float(self.size[a])
            return n * (n - 1) / 2
        return float(self.size[a]) * float(self.size[b])

    def _err_of(self, a: int) -> float:
        tot = _pair_err(self.selfc[a], self._pi(a, a))
        for b, cnt in self.adj[a].items():
            tot += _pair_err(cnt, self._pi(a, b))
        return tot

    def delta_re1(self, a: int, b: int) -> float:
        """Exact ΔRE₁ of merging a,b (union over both neighbor maps)."""
        before = self._err_of(a) + self._err_of(b) - _pair_err(
            self.adj[a].get(b, 0.0), self._pi(a, b)
        )
        nn = float(self.size[a] + self.size[b])
        w_ab = self.adj[a].get(b, 0.0)
        after = _pair_err(self.selfc[a] + self.selfc[b] + w_ab,
                          nn * (nn - 1) / 2)
        nbrs = set(self.adj[a]) | set(self.adj[b])
        nbrs.discard(a); nbrs.discard(b)
        for c in nbrs:
            cnt = self.adj[a].get(c, 0.0) + self.adj[b].get(c, 0.0)
            after += _pair_err(cnt, nn * float(self.size[c]))
        return after - before

    def merge(self, a: int, b: int) -> None:
        if a > b:
            a, b = b, a
        w_ab = self.adj[a].pop(b, 0.0)
        self.adj[b].pop(a, None)
        self.selfc[a] += self.selfc[b] + w_ab
        for c, cnt in self.adj[b].items():
            self.adj[c].pop(b, None)
            self.adj[a][c] = self.adj[a].get(c, 0.0) + cnt
            self.adj[c][a] = self.adj[a][c]
        self.adj[b] = {}
        self.members[a].extend(self.members[b])
        for u in self.members[b]:
            self.n2s[u] = a
        self.members[b] = []
        self.size[a] += self.size[b]
        self.size[b] = 0

    def run(self, target_supernodes: int, c: float = 1.0) -> BaselineResult:
        t0 = time.perf_counter()
        alive = list(np.flatnonzero(self.size > 0))
        while len(alive) > max(target_supernodes, 2):
            n_samples = max(int(c * len(alive)), 1)
            best, best_pair = np.inf, None
            idx = self.rng.integers(0, len(alive), size=(n_samples, 2))
            for i, j in idx:
                if i == j:
                    continue
                a, b = int(alive[i]), int(alive[j])
                d = self.delta_re1(a, b)
                if d < best:
                    best, best_pair = d, (a, b)
            if best_pair is None:
                break
            self.merge(*best_pair)
            alive = list(np.flatnonzero(self.size > 0))
        # compact ids for evaluation
        res = evaluate_partition(self.src, self.dst, self.v, self.n2s, "kgs")
        res.wall_s = time.perf_counter() - t0
        return res


def summarize_kgs(src, dst, num_nodes: int, target_frac: float = 0.3,
                  c: float = 1.0, seed: int = 0) -> BaselineResult:
    return KGs(src, dst, num_nodes, seed).run(
        max(int(target_frac * num_nodes), 2), c=c
    )
