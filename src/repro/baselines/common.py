"""Shared evaluation for the paper's baselines (k-Gs, S2L, SAA-Gs).

The competitors constrain the *number of supernodes* and keep every nonzero
superedge (no sparsification) — exactly why Fig. 4 shows their size in bits
often exceeding the input's. ``evaluate_partition`` computes Eq. (2)/(4)
for such a summary from an arbitrary node→supernode assignment, with the
same closed forms as ``repro.core.costs`` (numpy, sort + reduceat)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BaselineResult:
    name: str
    node2super: np.ndarray
    num_supernodes: int
    num_superedges: int
    size_bits: float
    input_size_bits: float
    re1: float
    re2: float
    wall_s: float = 0.0


def pair_counts(src, dst, n2s: np.ndarray):
    """Aggregate subedges into supernode-pair counts (lo ≤ hi)."""
    su = n2s[src]
    sv = n2s[dst]
    lo = np.minimum(su, sv).astype(np.int64)
    hi = np.maximum(su, sv).astype(np.int64)
    key = lo * (n2s.max() + 1 or 1) + hi
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    starts = np.flatnonzero(np.concatenate([[True], key_s[1:] != key_s[:-1]]))
    cnt = np.diff(np.concatenate([starts, [key.shape[0]]]))
    return lo[order][starts], hi[order][starts], cnt.astype(np.float64)


def evaluate_partition(src, dst, num_nodes: int, n2s: np.ndarray,
                       name: str = "") -> BaselineResult:
    src = np.asarray(src); dst = np.asarray(dst)
    n2s = np.asarray(n2s, dtype=np.int64)
    sizes = np.bincount(n2s, minlength=int(n2s.max()) + 1).astype(np.float64)
    s_count = int((sizes > 0).sum())
    plo, phi, cnt = pair_counts(src, dst, n2s)
    na, nb = sizes[plo], sizes[phi]
    pi = np.where(plo == phi, na * (na - 1) / 2.0, na * nb)
    sigma = cnt / np.maximum(pi, 1.0)

    re1 = float((2.0 * cnt * (1.0 - sigma)).sum())
    re2sq = float((cnt * (1.0 - sigma)).sum())
    v = float(num_nodes)
    denom = v * (v - 1.0)
    p = int(len(cnt))
    w_max = max(float(cnt.max()) if p else 2.0, 2.0)
    log2s = np.log2(max(s_count, 2))
    size_bits = p * (2 * log2s + np.log2(w_max)) + v * log2s
    input_bits = 2.0 * len(src) * np.log2(max(num_nodes, 2))
    return BaselineResult(
        name=name,
        node2super=n2s.astype(np.int32),
        num_supernodes=s_count,
        num_superedges=p,
        size_bits=float(size_bits),
        input_size_bits=float(input_bits),
        re1=2.0 * re1 / denom,
        re2=float(np.sqrt(2.0 * re2sq)) / denom,
    )


def adjacency_dicts(src, dst, num_nodes: int):
    """{a: {b: cnt}} supernode adjacency for the greedy baselines."""
    adj: list[dict[int, float]] = [dict() for _ in range(num_nodes)]
    for a, b in zip(np.asarray(src), np.asarray(dst)):
        a, b = int(a), int(b)
        adj[a][b] = adj[a].get(b, 0) + 1
        adj[b][a] = adj[b].get(a, 0) + 1
    return adj
