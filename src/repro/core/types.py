"""Core data structures for SSumM: graphs, summary state, pair tables.

All structures are fixed-shape pytrees so every phase of the algorithm is
jit-compilable. ``V``/``E`` are static; supernode ids live in ``[0, V)`` and
dead ids are marked by ``size == 0``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _pytree(cls):
    """Register a dataclass as a pytree (all fields are children)."""
    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return tuple(getattr(obj, f) for f in fields), None

    def unflatten(_, children):
        return cls(*children)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@_pytree
@dataclasses.dataclass
class Graph:
    """Canonical undirected simple graph: ``src < dst``, no self-loops, unique."""

    src: jax.Array  # int32[E]
    dst: jax.Array  # int32[E]

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def num_nodes_static(self) -> int:
        raise NotImplementedError("use Graph holders with explicit V (see make_graph)")


@_pytree
@dataclasses.dataclass
class SummaryState:
    """Functional state of the summarization search.

    ``node2super[v]`` maps every subnode to its current supernode id.
    ``size[a]`` is the number of subnodes in supernode ``a`` (0 = dead id).
    """

    node2super: jax.Array  # int32[V]
    size: jax.Array  # int32[V]
    rng: jax.Array  # PRNG key
    t: jax.Array  # int32 scalar, 1-based iteration counter

    @property
    def num_supernodes(self) -> jax.Array:
        return jnp.sum(self.size > 0).astype(jnp.int32)


@_pytree
@dataclasses.dataclass
class PairTable:
    """Aggregated supernode-pair table derived from the edge list.

    Fixed capacity ``E`` rows (a partition can induce at most ``E`` distinct
    supernode pairs with nonzero subedge count). ``valid`` masks live rows.
    Self-pairs are rows with ``lo == hi``.
    """

    lo: jax.Array  # int32[E]
    hi: jax.Array  # int32[E]
    cnt: jax.Array  # float32[E]  |E_AB| (exact integers in float32)
    valid: jax.Array  # bool[E]

    @property
    def capacity(self) -> int:
        return int(self.lo.shape[0])


@dataclasses.dataclass(frozen=True)
class SummaryConfig:
    """Hyper-parameters of the search (static; part of jit cache keys).

    Mirrors Sect. 3 of the paper; TPU-adaptation knobs are documented in
    DESIGN.md §3/§4.
    """

    T: int = 20  # outer iterations (paper default, Fig. 8)
    k_frac: float | None = None  # target size as a fraction of Size(G)
    k_bits: float | None = None  # absolute target size in bits
    group_size: int = 32  # C_max — candidate-set cap (paper: 500)
    max_neighbors: int = 64  # D_max — per-supernode scored-neighbor cap
    union_size: int = 128  # U_max — per-group union-neighbor columns
    cbar_mode: str = "tight"  # "paper": 2log2|V|+log2|E|; "tight": footnote 3
    re_guard: int = 1  # 0 = off; p in {1,2}: never keep superedges that raise RE_p
    error_p: int = 1  # p for the final sparsification deltas (footnote 4)
    ensure_budget: bool = True  # extra θ=0 iterations if membership term > k
    max_extra_iters: int = 40
    # merge-gain scoring backend, resolved through the kernel-dispatch
    # registry (repro.kernels.ops): "ref" (jitted jnp oracle — the XLA path
    # a CPU host runs), "pallas-interpret" (kernel body in Python, the CI
    # validation lane), or "pallas" (compiled, real accelerators). None
    # defers to $SSUMM_KERNEL, then "ref" — an explicit value here always
    # beats the environment.
    kernel_backend: str | None = None
    # R — merge rounds per device dispatch of the engine's chunked driver
    # (lax.while_loop; scalar metrics reach the host only on chunk
    # boundaries). 1 recovers the historical sync-every-round driver.
    driver_chunk: int = 8
    seed: int = 0

    def target_bits(self, size_g: float) -> float:
        if self.k_bits is not None:
            return float(self.k_bits)
        if self.k_frac is not None:
            return float(self.k_frac) * float(size_g)
        return 0.3 * float(size_g)


@dataclasses.dataclass
class SummaryResult:
    """Final output: the summary graph Ḡ = (S, P, ω) plus evaluation stats."""

    node2super: np.ndarray  # int32[V]
    super_size: np.ndarray  # int32[V]
    edge_lo: np.ndarray  # int32[P] superedge endpoints (supernode ids)
    edge_hi: np.ndarray  # int32[P]
    edge_w: np.ndarray  # int64[P] ω
    num_supernodes: int
    num_superedges: int
    size_bits: float  # Eq. (4)
    input_size_bits: float  # Eq. (3)
    re1: float  # normalized ℓ1 reconstruction error
    re2: float  # normalized ℓ2 reconstruction error
    mdl_cost: float  # Eq. (14)
    iterations_run: int
    history: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    # fault-tolerance / observability bookkeeping (engine pass-through;
    # DESIGN.md §13) — empty/zero when the run was plain and uninterrupted
    chunk_wall_s: list = dataclasses.field(default_factory=list)
    straggler_events: list = dataclasses.field(default_factory=list)
    resumed_from: int | None = None
    checkpoint_saves: int = 0
    checkpoint_snapshot_wall_s: float = 0.0


def make_graph(src, dst, num_nodes: int) -> tuple[Graph, int]:
    """Canonicalize an edge list: undirected, dedup, no self-loops, src<dst."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    key = lo * int(num_nodes) + hi
    _, idx = np.unique(key, return_index=True)
    lo, hi = lo[idx], hi[idx]
    g = Graph(src=jnp.asarray(lo, jnp.int32), dst=jnp.asarray(hi, jnp.int32))
    return g, int(num_nodes)


def init_state(num_nodes: int, seed: int = 0) -> SummaryState:
    """Ḡ := G (Alg. 1 lines 1–2): every subnode is its own supernode."""
    return SummaryState(
        node2super=jnp.arange(num_nodes, dtype=jnp.int32),
        size=jnp.ones((num_nodes,), dtype=jnp.int32),
        rng=jax.random.PRNGKey(seed),
        t=jnp.asarray(1, jnp.int32),
    )
