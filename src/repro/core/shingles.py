"""Candidate generation (Sect. 3.2.2): min-hash shingles → candidate groups.

Paper: supernodes sharing a shingle are within 2 hops; oversized groups are
split recursively (≤10×) then randomly, capped at 500 supernodes.

TPU adaptation (DESIGN.md §3): the random bijection ``h`` is a sampled
permutation; ``f(A)`` is computed with two segment-min passes; grouping is
one sort by ``(dead, shingle, rand)`` followed by fixed-size chunking into
``[G, C]`` tiles. Chunk boundaries may mix adjacent shingles — such pairs
are simply scored low and rejected by θ(t), so correctness is unaffected.
Randomness is refreshed every iteration, which subsumes the paper's
recursive re-splitting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SummaryState


def node_shingles(
    src: jax.Array, dst: jax.Array, num_nodes: int, rng: jax.Array
) -> jax.Array:
    """Per-subnode ``min(h(u), min_{(u,v)∈E} h(v))`` for a fresh bijection h."""
    h = jax.random.permutation(rng, num_nodes).astype(jnp.int32)
    f = h  # include h(u) itself (closed neighborhood)
    f = f.at[src].min(h[dst])
    f = f.at[dst].min(h[src])
    return f


def supernode_shingles(
    src: jax.Array, dst: jax.Array, state: SummaryState, rng: jax.Array
) -> jax.Array:
    """``f(A) = min_{u∈A} node_shingle(u)`` via one more segment-min pass."""
    num_nodes = state.node2super.shape[0]
    nf = node_shingles(src, dst, num_nodes, rng)
    out = jnp.full((num_nodes,), num_nodes, dtype=jnp.int32)
    out = out.at[state.node2super].min(nf)
    return out  # dead ids keep the sentinel ``num_nodes``


def chunk_groups(
    shingle: jax.Array,
    size: jax.Array,
    rng: jax.Array,
    group_size: int,
) -> jax.Array:
    """Sort supernodes by (dead, shingle, random) and chunk into ``[G, C]``.

    Active supernodes sharing a shingle land in the same chunk; dead ids are
    pushed to trailing groups (which cannot produce merges since their sizes
    are 0). ``V`` is padded to a multiple of ``C`` with the id ``-1``.
    """
    num_nodes = shingle.shape[0]
    dead = (size <= 0).astype(jnp.int32)
    tie = jax.random.permutation(rng, num_nodes).astype(jnp.int32)
    ids = jnp.arange(num_nodes, dtype=jnp.int32)
    # lexicographic: (dead, shingle, random) — three int32 keys
    _, _, _, order = jax.lax.sort((dead, shingle, tie, ids), num_keys=3)
    pad = (-num_nodes) % group_size
    if pad:
        order = jnp.concatenate([order, jnp.full((pad,), -1, jnp.int32)])
    return order.reshape(-1, group_size)


def chunk_groups_lean(shingle: jax.Array, group_size: int) -> jax.Array:
    """2-key variant of :func:`chunk_groups` (§Perf ssumm iteration 1).

    Requires shingles that already carry the dead sentinel (``num_nodes``
    for dead ids — what ``supernode_shingles``/``_local_supernode_shingles``
    produce), so the (dead, …) key is redundant; id order breaks ties
    (randomness comes from the per-iteration re-draw of ``h``). Halves the
    bytes moved by the dominant [V]-sized sort."""
    num_nodes = shingle.shape[0]
    ids = jnp.arange(num_nodes, dtype=jnp.int32)
    _, order = jax.lax.sort((shingle, ids), num_keys=2)
    pad = (-num_nodes) % group_size
    if pad:
        order = jnp.concatenate([order, jnp.full((pad,), -1, jnp.int32)])
    return order.reshape(-1, group_size)


def build_groups(
    src: jax.Array,
    dst: jax.Array,
    state: SummaryState,
    rng: jax.Array,
    group_size: int,
) -> jax.Array:
    """Candidate groups from subnode-level shingles (single-device path)."""
    k_shingle, k_tie = jax.random.split(rng)
    sh = supernode_shingles(src, dst, state, k_shingle)
    return chunk_groups(sh, state.size, k_tie, group_size)


def build_groups_from_pairs(
    plo: jax.Array,
    phi: jax.Array,
    pvalid: jax.Array,
    size: jax.Array,
    rng: jax.Array,
    group_size: int,
) -> jax.Array:
    """Candidate groups from *supergraph-level* shingles.

    Distributed path: each owner device holds the full superedge adjacency
    of its owned supernodes, so ``f(A) = min(h(A), min_{{A,B}∈P} h(B))`` is
    computable locally and exactly. This lifts the paper's subnode shingle
    to the summary graph (the SWeG-style variant); 2-hop locality in the
    supergraph implies 2-hop locality in G.
    """
    num_nodes = size.shape[0]
    k_shingle, k_tie = jax.random.split(rng)
    h = jax.random.permutation(k_shingle, num_nodes).astype(jnp.int32)
    f = h
    ok = pvalid & (plo != phi)
    sent = jnp.int32(num_nodes)
    f = f.at[jnp.where(ok, plo, sent)].min(
        jnp.where(ok, h[jnp.minimum(phi, num_nodes - 1)], sent), mode="drop"
    )
    f = f.at[jnp.where(ok, phi, sent)].min(
        jnp.where(ok, h[jnp.minimum(plo, num_nodes - 1)], sent), mode="drop"
    )
    return chunk_groups(f, size, k_tie, group_size)
