"""Distributed SSumM: edge-sharded summarization under shard_map.

Scale story (the paper's headline): one 64 GB host caps the reference
implementation at ~0.8 B edges; here edges are sharded over every mesh axis
while the partition vector (``node2super``/``size``, 4 B/node) is replicated
— web-uk-05 (39.5 M nodes, 0.78 B edges) takes ~12 MB of edges + ~316 MB of
replicated state per chip on a 256-chip pod (dry-run proof in EXPERIMENTS.md
§Dry-run).

Scheme (DESIGN.md §7):
  * **ownership**: two interchangeable groupings of one backend
    (:func:`make_distributed_backend`):

      - ``grouping="hash"`` — supernode ``A`` is owned by device
        ``hash_t(A) mod n_dev``; the hash is re-drawn every iteration so all
        supernode pairs are eventually co-owned (candidate sets never span
        owners — the exact analogue of the paper's disjoint candidate sets);
      - ``grouping="compact"`` — candidate groups are computed identically on
        every device (shingle pmin + replicated chunking) and device ``d``
        owns groups ``g ≡ d (mod n_dev)``, with compact ``[G_own·C, D]``
        neighbor tables (~40 MB at web-uk scale, where the hash path's
        ``[V, D]`` tables would be ~20 GB/device);

  * **pair exchange**: each device aggregates its local edge shard into
    partial (lo, hi, cnt) pair records and routes each record to *both*
    endpoint owners with a fixed-capacity ``all_to_all`` bucket shuffle;
    owners re-aggregate to exact global pair counts;
  * **merge round**: owners build group tables and run the merge-gain kernel
    locally (dispatched through the :mod:`repro.kernels.ops` registry);
    accepted (a, b) merge lists are ``all_gather``-ed and applied
    identically to the replicated partition on every device;
  * **metrics**: per-pair closed forms are summed over *lo-owned* pairs only
    (each pair counted once), ``psum``-ed, with ω_max ``pmax``-ed first so
    Size(Ḡ) is bit-identical to the single-device evaluation.

Bucket overflow (records beyond capacity) is counted and reported in the
stats — with the default capacity factor the shuffle is exact; tests verify
equality with the single-device pair table on multihost CPU meshes.

The final drop-to-k-bits phase (Sect. 3.2.4) is edge-sharded too (the
backend's ``sparsify``, DESIGN.md §7): pairs are exchanged to their *lo*
owner only (each pair counted exactly once), the ξ-th smallest ΔRE is found
by the psum'd histogram selection of
:func:`repro.core.sparsify.radix_select_kth` instead of a replicated sort,
and the resulting drop mask stays sharded — the whole pipeline
(merge → sparsify → metrics) runs without gathering edges to one host.

The iteration *driver* is the engine's (DESIGN.md §12):
:class:`DistributedBackend` plugs into
:class:`repro.core.engine.SummaryEngine`, and its ``run_chunk`` runs up to
``cfg.driver_chunk`` merge rounds per dispatch inside a ``lax.while_loop``
*within* the shard_map body — scalar metrics cross to the host only on
chunk boundaries instead of a full device→host sync every round.

Edge shards themselves arrive through :mod:`repro.graphs.feed`
(DESIGN.md §11): real graphs are sliced straight out of the mmap'd binary
CSR cache into per-device shards (host staging = one shard, never a
full-|E| array), so the backend receives inputs already committed to
``MeshRules.edge_spec`` and nothing upstream densifies the edge list.

``make_distributed_step`` / ``make_distributed_step_compact`` /
``make_distributed_sparsify`` remain as thin compat shims over the one
backend builder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, shingles, sparsify, tables
from repro.core.merge import apply_merges, select_matching
from repro.core.types import PairTable, SummaryConfig, SummaryState, init_state
from repro.dist import make_rules, shard_map
from repro.kernels import ops as kops
from repro.utils import boundaries_from_keys, segment_ids_from_boundaries

# Per-round scalar stats of the distributed merge step (fixed key set →
# fixed-shape on-device chunk buffers; see engine.Backend).
DIST_STAT_KEYS = (
    "size_bits",
    "re1",
    "nmerges",
    "num_supernodes",
    "num_superedges",
    "overflow",
)


def _ordered_psum(x, axis_names):
    """Order-invariant float sum across the mesh: all_gather the per-device
    partials, then reduce them locally in device order. A raw ``psum``'s
    partial-sum grouping depends on the process topology (gloo's
    cross-process ring groups differently from the single-process
    reduction, ~1 ulp on f32 accumulations), which would break the
    launcher-JSON bit-identity contract between single- and multi-process
    runs of the same global mesh (DESIGN.md §15). Integer-valued psums
    (counts, histograms) are exact in any order and stay plain ``psum``."""
    return jnp.sum(jax.lax.all_gather(x, axis_names), axis=0)


def _local_pairs(src, dst, node2super, num_nodes: int):
    """Local partial pair table from this device's edge shard (sorted)."""
    e = src.shape[0]
    pad = src < 0  # padded edge slots
    su = jnp.where(pad, num_nodes, node2super[jnp.maximum(src, 0)])
    sv = jnp.where(pad, num_nodes, node2super[jnp.maximum(dst, 0)])
    lo = jnp.minimum(su, sv)
    hi = jnp.maximum(su, sv)
    lo_s, hi_s = jax.lax.sort((lo, hi), num_keys=2)
    is_new = boundaries_from_keys(lo_s, hi_s)
    pid = segment_ids_from_boundaries(is_new)
    cnt = jax.ops.segment_sum(
        jnp.where(lo_s < num_nodes, 1.0, 0.0), pid, num_segments=e
    )
    plo = jnp.zeros((e,), jnp.int32).at[pid].max(lo_s)
    phi = jnp.zeros((e,), jnp.int32).at[pid].max(hi_s)
    valid = (jnp.arange(e) <= pid[-1]) & (plo < num_nodes) & (cnt > 0)
    return plo, phi, jnp.where(valid, cnt, 0.0), valid


def _route(plo, phi, cnt, valid, owner, n_dev: int, cap: int):
    """Pack pair records into per-destination buckets [n_dev, cap, 3]."""
    n = plo.shape[0]
    dest = jnp.where(valid, owner, n_dev)
    order = jnp.argsort(dest)
    dest_s = dest[order]
    is_new = boundaries_from_keys(dest_s)
    idx = jnp.arange(n, dtype=jnp.int32)
    start = jax.lax.cummax(jnp.where(is_new, idx, 0))
    slot = idx - start
    ok = (slot < cap) & (dest_s < n_dev)
    flat = jnp.where(ok, dest_s * cap + slot, n_dev * cap)
    rec = jnp.stack(
        [plo[order].astype(jnp.float32), phi[order].astype(jnp.float32), cnt[order]],
        axis=-1,
    )
    buck = jnp.full((n_dev * cap + 1, 3), -1.0, jnp.float32)
    buck = buck.at[flat].set(rec, mode="drop")[:-1]
    overflow = jnp.sum(((~ok) & (dest_s < n_dev)).astype(jnp.int32))
    return buck.reshape(n_dev, cap, 3), overflow


def _aggregate(recv, num_nodes: int):
    """Merge partial pair records from all sources into exact global counts."""
    rlo = recv[:, 0].astype(jnp.int32)
    rhi = recv[:, 1].astype(jnp.int32)
    rvalid = recv[:, 0] >= 0
    key_lo = jnp.where(rvalid, rlo, num_nodes)
    key_hi = jnp.where(rvalid, rhi, num_nodes)
    rcnt = jnp.where(rvalid, recv[:, 2], 0.0)
    klo, khi, kcnt = jax.lax.sort((key_lo, key_hi, rcnt), num_keys=2)
    is_new = boundaries_from_keys(klo, khi)
    pid = segment_ids_from_boundaries(is_new)
    m = klo.shape[0]
    gcnt = jax.ops.segment_sum(kcnt, pid, num_segments=m)
    glo = jnp.zeros((m,), jnp.int32).at[pid].max(klo)
    ghi = jnp.zeros((m,), jnp.int32).at[pid].max(khi)
    gvalid = (jnp.arange(m) <= pid[-1]) & (glo < num_nodes) & (gcnt > 0)
    return glo, ghi, jnp.where(gvalid, gcnt, 0.0), gvalid


def _exchange(plo, phi, cnt, valid, own_lo, own_hi, axis_names, n_dev, cap,
              num_nodes):
    """Route partial pair records to their owner(s) and re-aggregate.

    ``own_hi=None`` routes each pair to its *lo* owner only (the sparsify
    phase — each pair counted exactly once); otherwise records go to both
    endpoint owners (the merge phase — owners need their full adjacency).
    """
    b1, of1 = _route(plo, phi, cnt, valid, own_lo, n_dev, cap)
    if own_hi is None:
        buck, overflow = b1, of1
    else:
        b2, of2 = _route(plo, phi, cnt, valid & (own_hi != own_lo), own_hi,
                         n_dev, cap)
        buck = jnp.concatenate([b1, b2], axis=1)  # [n_dev, 2cap, 3]
        overflow = of1 + of2
    recv = jax.lax.all_to_all(
        buck, axis_names, split_axis=0, concat_axis=0, tiled=True
    )
    glo, ghi, gcnt, gvalid = _aggregate(recv.reshape(-1, 3), num_nodes)
    return glo, ghi, gcnt, gvalid, overflow


def _round_metrics(cfg, state, glo, ghi, gcnt, mine, cbar, log2v, v,
                   axis_names, s_count, nmerges_g, overflow):
    """Exact global Eq. (4)/(2) metrics over lo-owned pairs (psum'd)."""
    pi = costs.pair_pi(PairTable(lo=glo, hi=ghi, cnt=gcnt, valid=mine),
                       state.size)
    glo_c = jnp.clip(glo, 0, v - 1)
    ghi_c = jnp.clip(ghi, 0, v - 1)
    touched = (state.size[glo_c] > 1) | (state.size[ghi_c] > 1)
    decided = costs.keep_superedge(gcnt, pi, cbar, jnp.float32(log2v),
                                   cfg.re_guard)
    keep = jnp.where(touched, decided, gcnt > 0.0) & mine
    cntk = jnp.where(keep, gcnt, 0.0)
    sigma = jnp.where(keep, gcnt / jnp.maximum(pi, 1.0), 0.0)
    re1_local = jnp.sum(2.0 * cntk * (1.0 - sigma)) + jnp.sum(
        jnp.where(mine & ~keep, gcnt, 0.0))
    p_total = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), axis_names)
    w_total = jax.lax.pmax(jnp.max(cntk), axis_names)
    re1_total = _ordered_psum(re1_local, axis_names)
    log2s = jnp.log2(jnp.maximum(s_count, 2.0))
    log2w = jnp.log2(jnp.maximum(w_total, 2.0))
    size_bits = p_total * (2.0 * log2s + log2w) + v * log2s
    return {
        "size_bits": size_bits,
        "re1": 2.0 * re1_total / (float(v) * (v - 1.0)),
        "num_superedges": p_total,
        "num_supernodes": s_count,
        "nmerges": nmerges_g,
        "overflow": jax.lax.psum(overflow, axis_names),
    }


def _local_supernode_shingles(src_l, dst_l, node2super, h, num_nodes):
    """Per-supernode min-hash from the local edge shard (pmin-able)."""
    pad = src_l < 0
    s_safe = jnp.maximum(src_l, 0)
    d_safe = jnp.maximum(dst_l, 0)
    sent = jnp.int32(num_nodes)
    f = h  # closed neighborhood: own hash first
    f = f.at[jnp.where(pad, sent, s_safe)].min(
        jnp.where(pad, sent, h[d_safe]), mode="drop")
    f = f.at[jnp.where(pad, sent, d_safe)].min(
        jnp.where(pad, sent, h[s_safe]), mode="drop")
    out = jnp.full((num_nodes,), num_nodes, jnp.int32)
    out = out.at[node2super].min(f)
    return out


class DistributedBackend:
    """Engine :class:`~repro.core.engine.Backend` over an edge-sharded mesh.

    Built by :func:`make_distributed_backend`. Holds the jitted step /
    sparsify / chunk programs; call :meth:`bind` with the per-device edge
    shards before handing it to :class:`~repro.core.engine.SummaryEngine`.
    The raw programs remain addressable for direct use:

      * ``step(src_l, dst_l, state, θ, salt)`` — one merge round
        (``(..., groups_all)`` with ``external_groups=True``);
      * ``sparsify(src_l, dst_l, state, k_bits, salt)`` — Sect. 3.2.4 tail;
      * ``chunk(src_l, dst_l, state, θ[R], t0, k_bits, limit)`` — the
        device-resident multi-round driver.
    """

    stat_keys = DIST_STAT_KEYS

    def __init__(self, mesh, cfg: SummaryConfig, num_nodes: int,
                 num_edges: int, step, sparsify_fn, chunk):
        self.mesh = mesh
        self.cfg = cfg
        self.num_nodes = num_nodes
        self.num_edges = num_edges
        self.step = step
        self.sparsify = sparsify_fn
        self.chunk = chunk
        self._src = None
        self._dst = None

    def bind(self, src_p, dst_p) -> "DistributedBackend":
        """Attach the per-device edge shards the engine will drive over."""
        self._src, self._dst = src_p, dst_p
        return self

    def _shards(self):
        if self._src is None:
            raise ValueError("DistributedBackend: call bind(src_p, dst_p) "
                             "with edge shards before running the engine")
        return self._src, self._dst

    # ---- engine Backend protocol ---------------------------------------
    def input_size_bits(self) -> float:
        return 2.0 * self.num_edges * float(np.log2(max(self.num_nodes, 2)))

    def init(self) -> SummaryState:
        return init_state(self.num_nodes, self.cfg.seed)

    def run_chunk(self, state, thetas, t0, k_bits, limit):
        src_p, dst_p = self._shards()
        with self.mesh:
            return self.chunk(src_p, dst_p, state, thetas,
                              jnp.uint32(t0), jnp.float32(k_bits),
                              jnp.int32(limit))

    def num_supernodes(self, state) -> int:
        return int(jnp.sum(state.size > 0))

    def state_sharding(self):
        """Replicated placement on *this* mesh — restoring a checkpoint
        written on a different device count resolves here (DESIGN.md §13:
        reshard-on-load, no resharding pass)."""
        from jax.sharding import NamedSharding

        return NamedSharding(self.mesh,
                             make_rules(self.mesh, "summarize").replicated)

    def sparsify_finalize(self, state, k_bits, salt) -> dict:
        src_p, dst_p = self._shards()
        with self.mesh:
            stats, pairs = self.sparsify(src_p, dst_p, state,
                                         jnp.float32(k_bits),
                                         jnp.uint32(salt))
        return {"stats": stats, "pairs": pairs}


def make_distributed_backend(mesh, cfg: SummaryConfig, num_nodes: int,
                             num_edges_global: int, *,
                             grouping: str = "compact",
                             capacity_factor: float = 4.0,
                             lean_sort: bool = False,
                             external_groups: bool = False,
                             ) -> DistributedBackend:
    """Build the one edge-sharded backend for ``mesh`` (DESIGN.md §7/§12).

    ``grouping`` selects candidate-set ownership: ``"hash"`` (re-drawn
    supernode hash, [V, D] tables — fine through LiveJournal scale) or
    ``"compact"`` (group-owner sharding with compact tables — the web-scale
    path). ``lean_sort`` selects the 2-key grouping sort (§Perf ssumm
    iter. 1); ``external_groups`` makes the step take a precomputed
    ``groups_all`` ([G_pad, C], from :func:`make_grouping_fn`) as a sixth
    argument so the grouping can run every ``regroup_every``-th iteration
    (§Perf iter. C2). Inputs at call time: padded edge shards
    (int32[E_pad], -1 padding), replicated ``SummaryState``, θ scalar, and
    an ownership salt.
    """
    if grouping not in ("hash", "compact"):
        raise ValueError(f"unknown grouping {grouping!r}; "
                         f"valid: ['compact', 'hash']")
    rules = make_rules(mesh, "summarize")
    axis_names = rules.axis_names
    n_dev = rules.n_devices
    v = num_nodes
    c = cfg.group_size
    g_total = -(-v // c)
    g_pad = -(-g_total // n_dev) * n_dev
    g_own = g_pad // n_dev
    n_rows = g_own * c  # owned supernode slots per device (compact)
    log2v = float(np.log2(max(v, 2)))
    kernel = kops.resolve_kernel_backend(cfg.kernel_backend)

    def bucket_cap(e_loc: int) -> int:
        # a destination can never receive more records than the sender
        # has valid pairs (≤ e_loc), so capacity beyond e_loc is pure
        # bucket memory waste — at web/CI scale the uncapped factor
        # allocated multi-GB buckets for provably-empty slots
        return min(int(e_loc * capacity_factor / n_dev), e_loc) + 8

    def cbar_of(s_count, omega_all):
        if cfg.cbar_mode == "paper":
            return jnp.float32(2.0 * log2v
                               + float(np.log2(max(num_edges_global, 2))))
        return 2.0 * jnp.log2(s_count) + jnp.log2(
            jnp.maximum(omega_all, 2.0))

    # ---- one merge round, per-shard body --------------------------------
    def step_hash(src_l, dst_l, state: SummaryState, theta, salt):
        cap = bucket_cap(src_l.shape[0])
        plo, phi, cnt, valid = _local_pairs(src_l, dst_l, state.node2super, v)
        glo, ghi, gcnt, gvalid, overflow = _exchange(
            plo, phi, cnt, valid, rules.owner(plo, salt),
            rules.owner(phi, salt), axis_names, n_dev, cap, v)
        dev = jax.lax.axis_index(axis_names)

        s_count = jnp.maximum(jnp.sum(state.size > 0).astype(jnp.float32), 2.0)
        omega_all = jax.lax.pmax(jnp.max(jnp.where(gvalid, gcnt, 0.0)),
                                 axis_names)
        cbar = cbar_of(s_count, omega_all)

        owned = rules.owner(jnp.arange(v, dtype=jnp.int32), salt) == dev
        groups = shingles.build_groups_from_pairs(
            glo, ghi, gvalid, jnp.where(owned, state.size, 0),
            jax.random.fold_in(state.rng, dev), cfg.group_size,
        )
        pt = PairTable(lo=glo, hi=ghi, cnt=gcnt, valid=gvalid)
        gt = tables.build_group_tables(
            pt, state, groups, cfg.max_neighbors, cfg.union_size, cbar, v
        )
        rel, _ = kops.merge_gain(
            gt.m, gt.n, gt.s, gt.t, gt.n_u, gt.cidx, gt.w, cbar,
            jnp.float32(log2v), backend=kernel,
        )
        a, b, sel = select_matching(rel, gt.members, theta)
        # ownership discipline: only merges between two *owned* supernodes
        # are valid on this device — trailing groups may contain non-owned
        # (masked-dead) ids whose sizes are live in the shared tables.
        a_safe = jnp.clip(a, 0, v - 1)
        b_safe = jnp.clip(b, 0, v - 1)
        sel = sel & owned[a_safe] & owned[b_safe]
        a_all = jax.lax.all_gather(a, axis_names, tiled=True)
        b_all = jax.lax.all_gather(b, axis_names, tiled=True)
        sel_all = jax.lax.all_gather(sel, axis_names, tiled=True)
        new_state, nmerges_g = apply_merges(state, a_all, b_all, sel_all)

        mine = gvalid & (rules.owner(glo, salt) == dev)
        stats = _round_metrics(cfg, state, glo, ghi, gcnt, mine, cbar,
                               log2v, v, axis_names, s_count, nmerges_g,
                               overflow)
        new_state = SummaryState(
            node2super=new_state.node2super,
            size=new_state.size,
            rng=jax.random.fold_in(state.rng, 1729),
            t=state.t + 1,
        )
        return new_state, stats

    def step_compact(src_l, dst_l, state: SummaryState, theta, salt,
                     groups_in=None):
        del salt  # ownership re-randomizes through the shingle rng
        cap = bucket_cap(src_l.shape[0])
        dev = jax.lax.axis_index(axis_names)

        # ---- identical-everywhere candidate groups ----------------------
        k_h, k_tie, k_next = jax.random.split(state.rng, 3)
        if groups_in is not None:
            groups_all = groups_in
        else:
            h = jax.random.permutation(k_h, v).astype(jnp.int32)
            f_loc = _local_supernode_shingles(src_l, dst_l,
                                              state.node2super, h, v)
            f = jax.lax.pmin(f_loc, axis_names)
            if lean_sort:
                # dead ids already carry the sentinel shingle == V (§Perf)
                groups_all = shingles.chunk_groups_lean(f, c)
            else:
                groups_all = shingles.chunk_groups(f, state.size, k_tie, c)
            pad_rows = g_pad - groups_all.shape[0]
            if pad_rows:
                groups_all = jnp.concatenate(
                    [groups_all, jnp.full((pad_rows, c), -1, jnp.int32)])
        # device d owns groups ≡ d (mod n_dev)
        my_groups = jnp.take(
            groups_all.reshape(g_pad // n_dev, n_dev, c), dev, axis=1)

        # group-owner of every supernode id
        flat_members = groups_all.reshape(-1)
        gidx = jnp.arange(g_pad * c, dtype=jnp.int32) // c
        owner_of = jnp.zeros((v + 1,), jnp.int32).at[
            jnp.where(flat_members >= 0, flat_members, v)
        ].set(gidx % n_dev, mode="drop")[:-1]
        # owned-slot of every supernode id (-1 = not owned here)
        my_flat = my_groups.reshape(-1)
        slot_of = jnp.full((v + 1,), -1, jnp.int32).at[
            jnp.where(my_flat >= 0, my_flat, v)
        ].set(jnp.arange(n_rows, dtype=jnp.int32), mode="drop")[:-1]

        # ---- pair exchange to group owners ------------------------------
        plo, phi, cnt, valid = _local_pairs(src_l, dst_l, state.node2super, v)
        glo, ghi, gcnt, gvalid, overflow = _exchange(
            plo, phi, cnt, valid, owner_of[jnp.clip(plo, 0, v - 1)],
            owner_of[jnp.clip(phi, 0, v - 1)], axis_names, n_dev, cap, v)

        # ---- compact tables for owned groups -----------------------------
        s_count = jnp.maximum(jnp.sum(state.size > 0).astype(jnp.float32), 2.0)
        omega_all = jax.lax.pmax(jnp.max(jnp.where(gvalid, gcnt, 0.0)),
                                 axis_names)
        cbar = cbar_of(s_count, omega_all)

        nbr_id, nbr_cnt, self_cnt = tables.build_neighbor_tables_compact(
            glo, ghi, gcnt, gvalid, slot_of, n_rows, v, cfg.max_neighbors)
        t_all = tables.supernode_total_costs_compact(
            glo, ghi, gcnt, gvalid, slot_of, n_rows, v, state.size, cbar,
            jnp.float32(log2v))
        gt = tables.assemble_group_tables(
            nbr_id, nbr_cnt, self_cnt, t_all, state.size, my_groups,
            row_of_member=slot_of, union_size=cfg.union_size, num_nodes=v)
        rel, _ = kops.merge_gain(
            gt.m, gt.n, gt.s, gt.t, gt.n_u, gt.cidx, gt.w, cbar,
            jnp.float32(log2v), backend=kernel)
        a, b, sel = select_matching(rel, gt.members, theta)
        a_all = jax.lax.all_gather(a, axis_names, tiled=True)
        b_all = jax.lax.all_gather(b, axis_names, tiled=True)
        sel_all = jax.lax.all_gather(sel, axis_names, tiled=True)
        new_state, nmerges_g = apply_merges(state, a_all, b_all, sel_all)

        mine = gvalid & (owner_of[jnp.clip(glo, 0, v - 1)] == dev)
        stats = _round_metrics(cfg, state, glo, ghi, gcnt, mine, cbar,
                               log2v, v, axis_names, s_count, nmerges_g,
                               overflow)
        new_state = SummaryState(
            node2super=new_state.node2super, size=new_state.size,
            rng=k_next, t=state.t + 1)
        return new_state, stats

    step_shard = step_hash if grouping == "hash" else step_compact

    # ---- Sect. 3.2.4 further sparsification, per-shard body -------------
    def sparsify_shard(src_l, dst_l, state: SummaryState, k_bits, salt):
        cap = bucket_cap(src_l.shape[0])
        dev = jax.lax.axis_index(axis_names)

        # ---- pair exchange: each pair to its lo owner, counted once ------
        plo, phi, cnt, valid = _local_pairs(src_l, dst_l, state.node2super, v)
        glo, ghi, gcnt, gvalid, of = _exchange(
            plo, phi, cnt, valid, rules.owner(plo, salt), None,
            axis_names, n_dev, cap, v)
        mine = gvalid & (rules.owner(glo, salt) == dev)

        # ---- pre-drop metrics (identical to costs.summary_metrics) -------
        s_count = jnp.maximum(jnp.sum(state.size > 0).astype(jnp.float32), 2.0)
        pt = PairTable(lo=glo, hi=ghi, cnt=gcnt, valid=mine)
        pi = costs.pair_pi(pt, state.size)
        omega_all = jax.lax.pmax(jnp.max(jnp.where(mine, gcnt, 0.0)),
                                 axis_names)
        cbar = costs.cbar_value(cfg.cbar_mode, v, num_edges_global, s_count,
                                omega_all)
        glo_c = jnp.clip(glo, 0, v - 1)
        ghi_c = jnp.clip(ghi, 0, v - 1)
        touched = (state.size[glo_c] > 1) | (state.size[ghi_c] > 1)
        decided = costs.keep_superedge(gcnt, pi, cbar, jnp.float32(log2v),
                                       cfg.re_guard)
        keep = jnp.where(touched, decided, gcnt > 0.0) & mine
        cntk = jnp.where(keep, gcnt, 0.0)
        p_total = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), axis_names)
        w_total = jax.lax.pmax(jnp.max(cntk), axis_names)
        log2s = jnp.log2(jnp.maximum(s_count, 2.0))
        size_before = p_total * (2.0 * log2s
                                 + jnp.log2(jnp.maximum(w_total, 2.0))
                                 ) + v * log2s

        # ---- ξ and the distributed order statistic -----------------------
        delta = sparsify.sparsify_deltas(gcnt, pi, cfg.error_p)
        xi = sparsify.sparsify_xi(size_before, k_bits, s_count, w_total)
        delta_xi = sparsify.select_delta_xi(
            delta, keep, xi,
            reduce_hist=lambda h: jax.lax.psum(h, axis_names))
        drop = sparsify.drop_from_threshold(keep, delta, delta_xi, xi,
                                            p_total.astype(jnp.int32))

        # ---- post-drop metrics (Eq. 4 / Eq. 2 closed forms) --------------
        keep2 = keep & ~drop
        cntk2 = jnp.where(keep2, gcnt, 0.0)
        sigma2 = jnp.where(keep2, gcnt / jnp.maximum(pi, 1.0), 0.0)
        p2 = jax.lax.psum(jnp.sum(keep2.astype(jnp.float32)), axis_names)
        w2 = jax.lax.pmax(jnp.max(cntk2), axis_names)
        size_after = p2 * (2.0 * log2s + jnp.log2(jnp.maximum(w2, 2.0))
                           ) + v * log2s
        dropped_cnt = jnp.where(mine & ~keep2, gcnt, 0.0)
        re1_sum = _ordered_psum(
            jnp.sum(2.0 * cntk2 * (1.0 - sigma2)) + jnp.sum(dropped_cnt),
            axis_names)
        re2_sq = _ordered_psum(
            jnp.sum(cntk2 * (1.0 - sigma2)) + jnp.sum(dropped_cnt),
            axis_names)
        denom = float(v) * (v - 1.0)
        stats = {
            "size_bits": size_after,
            "size_bits_before": size_before,
            "re1": 2.0 * re1_sum / denom,
            "re2": jnp.sqrt(2.0 * re2_sq) / denom,
            "num_superedges": p2,
            "num_supernodes": s_count,
            "omega_max": w2,
            "xi": xi.astype(jnp.float32),
            "dropped": jax.lax.psum(jnp.sum(drop.astype(jnp.float32)),
                                    axis_names),
            "overflow": jax.lax.psum(of, axis_names),
        }
        pairs = {"lo": glo, "hi": ghi, "cnt": gcnt, "keep": keep2,
                 "drop": drop, "mine": mine}
        return stats, pairs

    # ---- device-resident chunked driver, per-shard body ------------------
    def chunk_shard(src_l, dst_l, state: SummaryState, thetas, t0, k_bits,
                    limit):
        r = thetas.shape[0]
        buf0 = {k: jnp.zeros((r,), jnp.float32) for k in DIST_STAT_KEYS}

        def cond(carry):
            i, _state, done, _buf = carry
            return (i < limit) & ~done

        def body(carry):
            i, state, _done, buf = carry
            theta = thetas[i]
            salt = t0 + i.astype(jnp.uint32)
            new_state, stats = step_shard(src_l, dst_l, state, theta, salt)
            buf = {
                k: buf[k].at[i].set(stats[k].astype(jnp.float32))
                for k in DIST_STAT_KEYS
            }
            done = (stats["size_bits"] <= k_bits) | (
                (stats["nmerges"] == 0) & (theta == 0.0)
            )
            return i + 1, new_state, done, buf

        rounds, state, _done, buf = jax.lax.while_loop(
            cond, body, (jnp.int32(0), state, jnp.bool_(False), buf0)
        )
        return state, buf, rounds

    spec_e = rules.edge_spec
    spec_r = rules.replicated
    if external_groups:
        if grouping != "compact":
            raise ValueError("external_groups requires grouping='compact'")

        def step_ext(src_l, dst_l, state, theta, salt, groups_all):
            return step_compact(src_l, dst_l, state, theta, salt, groups_all)

        step_sharded = shard_map(
            step_ext, mesh=mesh,
            in_specs=(spec_e, spec_e, spec_r, spec_r, spec_r, spec_r),
            out_specs=(spec_r, spec_r),
            check_vma=False,
        )
    else:
        step_sharded = shard_map(
            step_shard, mesh=mesh,
            in_specs=(spec_e, spec_e, spec_r, spec_r, spec_r),
            out_specs=(spec_r, spec_r),
            check_vma=False,
        )
    sparsify_sharded = shard_map(
        sparsify_shard, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_r, spec_r, spec_r),
        out_specs=(spec_r, spec_e),
        check_vma=False,
    )
    chunk_sharded = shard_map(
        chunk_shard, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_r, spec_r, spec_r, spec_r, spec_r),
        out_specs=(spec_r, spec_r, spec_r),
        check_vma=False,
    )
    return DistributedBackend(
        mesh, cfg, num_nodes, num_edges_global,
        step=jax.jit(step_sharded),
        sparsify_fn=jax.jit(sparsify_sharded),
        chunk=jax.jit(chunk_sharded),
    )


# ---------------------------------------------------------------------------
# Compat shims over the one backend builder
# ---------------------------------------------------------------------------


def make_distributed_step(mesh, cfg: SummaryConfig, num_nodes: int,
                          num_edges_global: int, capacity_factor: float = 4.0):
    """Compat shim: the hash-owner one-iteration step (backend ``.step``)."""
    return make_distributed_backend(
        mesh, cfg, num_nodes, num_edges_global, grouping="hash",
        capacity_factor=capacity_factor,
    ).step


def make_distributed_step_compact(mesh, cfg: SummaryConfig, num_nodes: int,
                                  num_edges_global: int,
                                  capacity_factor: float = 4.0,
                                  lean_sort: bool = False,
                                  external_groups: bool = False):
    """Compat shim: the group-owner (web-scale) step (backend ``.step``)."""
    return make_distributed_backend(
        mesh, cfg, num_nodes, num_edges_global, grouping="compact",
        capacity_factor=capacity_factor, lean_sort=lean_sort,
        external_groups=external_groups,
    ).step


def make_distributed_sparsify(mesh, cfg: SummaryConfig, num_nodes: int,
                              num_edges_global: int,
                              capacity_factor: float = 4.0):
    """Compat shim: the edge-sharded Sect. 3.2.4 phase (backend
    ``.sparsify``): ``(src_l, dst_l, state, k_bits, salt) → (stats, pairs)``
    with replicated ``stats`` and the still-sharded per-pair table."""
    return make_distributed_backend(
        mesh, cfg, num_nodes, num_edges_global, grouping="hash",
        capacity_factor=capacity_factor,
    ).sparsify


def pad_and_shard_edges(src, dst, mesh) -> tuple[jax.Array, jax.Array]:
    """Pad the edge list to a multiple of the device count (-1 padding).

    Compatibility shim over :func:`repro.graphs.feed.shard_edges` — the
    returned arrays are now *born sharded* per ``MeshRules.edge_spec``
    (identical contents to the historical full-host construction, but no
    full-|E| concatenate copy; DESIGN.md §11). Callers holding a CSR
    cache should feed it directly via
    :func:`repro.graphs.feed.shard_edges_from_cache` instead of
    densifying the mmap'd columns just to pass them here.
    """
    from repro.graphs.feed import shard_edges

    shards = shard_edges(src, dst, mesh)
    return shards.src, shards.dst


def make_grouping_fn(mesh, cfg: SummaryConfig, num_nodes: int,
                     lean_sort: bool = True):
    """Standalone candidate-grouping program (§Perf ssumm iteration C2).

    The grouping ([V]-sized shingle pmin + sort) is independent of the merge
    bookkeeping, so it can run every ``regroup_every``-th iteration and be
    amortized — the paper itself reuses candidate-set structure *within* an
    iteration (≤10 recursive re-splits before going random), so reusing a
    grouping for a small number of adjacent iterations is the same kind of
    coverage/efficiency trade, measured in EXPERIMENTS.md §Perf.

    Returns a jitted fn: (src_l, dst_l, state) → groups_all [G_pad, C]
    (replicated), with G padded to the mesh device count.
    """
    rules = make_rules(mesh, "summarize")
    axis_names = rules.axis_names
    n_dev = rules.n_devices
    v = num_nodes
    c = cfg.group_size
    g_total = -(-v // c)
    g_pad = -(-g_total // n_dev) * n_dev

    def fn(src_l, dst_l, state: SummaryState):
        k_h, k_tie, _ = jax.random.split(state.rng, 3)
        h = jax.random.permutation(k_h, v).astype(jnp.int32)
        f_loc = _local_supernode_shingles(src_l, dst_l, state.node2super, h, v)
        f = jax.lax.pmin(f_loc, axis_names)
        if lean_sort:
            groups_all = shingles.chunk_groups_lean(f, c)
        else:
            groups_all = shingles.chunk_groups(f, state.size, k_tie, c)
        pad_rows = g_pad - groups_all.shape[0]
        if pad_rows:
            groups_all = jnp.concatenate(
                [groups_all, jnp.full((pad_rows, c), -1, jnp.int32)])
        return groups_all

    spec_e = rules.edge_spec
    sharded = shard_map(
        fn, mesh=mesh, in_specs=(spec_e, spec_e, rules.replicated),
        out_specs=rules.replicated,
        check_vma=False,
    )
    return jax.jit(sharded)
