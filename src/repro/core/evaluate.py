"""Dense-reconstruction evaluation utilities (small graphs / tests only).

These build the |V|×|V| weighted adjacency Â of the reconstructed graph Ĝ
(Eq. 1) and evaluate RE_p by brute force (Eq. 2) — the ground truth against
which the closed-form pair-table evaluation in :mod:`repro.core.costs` is
verified. Never used at scale.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import SummaryResult


def reconstruct_dense(result: SummaryResult) -> np.ndarray:
    """Weighted adjacency Â of Ĝ from the summary graph (Eq. 1)."""
    n2s = result.node2super
    v = n2s.shape[0]
    size = result.super_size
    a_hat = np.zeros((v, v), dtype=np.float64)
    for lo, hi, w in zip(result.edge_lo, result.edge_hi, result.edge_w):
        mem_a = np.where(n2s == lo)[0]
        mem_b = np.where(n2s == hi)[0] if hi != lo else mem_a
        na, nb = size[lo], size[hi]
        pi = na * (na - 1) / 2 if lo == hi else na * nb
        if pi <= 0:
            continue
        weight = w / pi
        for i in mem_a:
            for j in mem_b:
                if i != j:
                    a_hat[i, j] = weight
                    a_hat[j, i] = weight
    return a_hat


def dense_adjacency(src, dst, num_nodes: int) -> np.ndarray:
    a = np.zeros((num_nodes, num_nodes), dtype=np.float64)
    a[src, dst] = 1.0
    a[dst, src] = 1.0
    return a


def re_p_dense(a: np.ndarray, a_hat: np.ndarray, p: int) -> float:
    """Eq. (2), normalized by |V|(|V|-1) (footnote 5)."""
    v = a.shape[0]
    diff = np.abs(a - a_hat)
    np.fill_diagonal(diff, 0.0)
    denom = v * (v - 1)
    if p == 1:
        return float(diff.sum() / denom)
    return float(np.sqrt((diff**2).sum()) / denom)


def summary_size_bits_dense(result: SummaryResult) -> float:
    """Eq. (4) recomputed from the realized summary graph arrays."""
    s = max(result.num_supernodes, 2)
    p = len(result.edge_w)
    if p == 0:
        return result.node2super.shape[0] * float(np.log2(s))
    w_max = max(int(result.edge_w.max()), 2)
    v = result.node2super.shape[0]
    return p * (2 * np.log2(s) + np.log2(w_max)) + v * np.log2(s)
