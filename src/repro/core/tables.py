"""Per-group neighbor tables: the operands of the merge-gain kernel.

For every candidate group of ``C`` supernodes we build a *dense union-space*
representation (DESIGN.md §5): the distinct neighbor supernodes of all group
members are assigned up to ``U`` columns, so the neighbor multiset of member
``i`` is a row ``M[i, :]`` and the neighbor multiset of a merged pair (i,j)
is simply ``M[i] + M[j]`` — turning the paper's sorted-list unions into MXU
friendly dense arithmetic.

Exactness contract: scoring sees the top-``D`` heaviest neighbors of each
member (≤ ``U`` union columns); everything that falls off the tables is
carried by the *exact* per-supernode totals ``t_A = Cost*_A(S)`` as a
``tail`` term that is held constant under a hypothetical merge (a lower
bound on the merged cost by Lemma B.1 — see DESIGN.md §3 ⚠). With
``D ≥ max degree`` the scoring is exact; tests enforce this against the
sequential oracle.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.types import PairTable, SummaryState, _pytree
from repro.utils import boundaries_from_keys, rank_in_segment


@_pytree
@dataclasses.dataclass
class GroupTables:
    """Operands for one merge-gain evaluation over all groups."""

    m: jax.Array  # float32[G, C, U]  member→union-neighbor subedge counts
    n: jax.Array  # float32[G, C]    member supernode sizes (0 = padding)
    s: jax.Array  # float32[G, C]    member self-loop subedge counts
    t: jax.Array  # float32[G, C]    exact Cost*_A(S) totals
    n_u: jax.Array  # float32[G, U]  union-neighbor supernode sizes
    cidx: jax.Array  # int32[G, C]   member's own column in U (U = absent)
    w: jax.Array  # float32[G, C, C] within-group pair subedge counts
    members: jax.Array  # int32[G, C] supernode ids (-1 = padding)


def build_neighbor_tables(
    pt: PairTable, num_nodes: int, max_neighbors: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-``D`` heaviest neighbors per supernode + self-loop counts.

    Returns ``(nbr_id int32[V, D], nbr_cnt float32[V, D], self_cnt float32[V])``
    with ``nbr_id == V`` marking empty slots.
    """
    v, d = num_nodes, max_neighbors
    nonself = pt.valid & (pt.lo != pt.hi)
    # two directed entries per undirected pair
    owner = jnp.concatenate([pt.lo, pt.hi])
    other = jnp.concatenate([pt.hi, pt.lo])
    cnt = jnp.concatenate([pt.cnt, pt.cnt])
    val = jnp.concatenate([nonself, nonself])
    owner_k = jnp.where(val, owner, v)  # invalid entries last
    neg_cnt = jnp.where(val, -cnt, 0.0)
    owner_s, _, other_s, cnt_s, val_s = jax.lax.sort(
        (owner_k, neg_cnt, other, cnt, val.astype(jnp.int32)), num_keys=2
    )
    is_new = boundaries_from_keys(owner_s)
    rank = rank_in_segment(is_new)
    keep = (rank < d) & (val_s > 0)
    flat = jnp.where(keep, owner_s * d + rank, v * d)  # OOB → dropped
    nbr_id = jnp.full((v * d,), v, jnp.int32).at[flat].set(other_s, mode="drop")
    nbr_cnt = jnp.zeros((v * d,), jnp.float32).at[flat].set(cnt_s, mode="drop")

    is_self = pt.valid & (pt.lo == pt.hi)
    self_cnt = jnp.zeros((v,), jnp.float32).at[
        jnp.where(is_self, pt.lo, v)
    ].add(jnp.where(is_self, pt.cnt, 0.0), mode="drop")
    return nbr_id.reshape(v, d), nbr_cnt.reshape(v, d), self_cnt


def build_neighbor_tables_compact(
    plo: jax.Array,
    phi: jax.Array,
    cnt: jax.Array,
    valid: jax.Array,
    slot_of: jax.Array,  # int32[V]: global id → compact row (-1 = not owned)
    n_rows: int,
    num_nodes: int,
    max_neighbors: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-``D`` neighbor tables for a *subset* of supernodes (the owned
    rows of one device) — [n_rows, D] instead of [V, D], which is what lets
    the distributed path scale to web-size V (DESIGN.md §7).

    Same dataflow as :func:`build_neighbor_tables` with row indices mapped
    through ``slot_of``. Self-loop counts are returned per row.
    """
    v, d = num_nodes, max_neighbors
    nonself = valid & (plo != phi)
    owner = jnp.concatenate([plo, phi])
    other = jnp.concatenate([phi, plo])
    cnt2 = jnp.concatenate([cnt, cnt])
    row = slot_of[jnp.clip(owner, 0, v - 1)]
    val = jnp.concatenate([nonself, nonself]) & (row >= 0)
    row_k = jnp.where(val, row, n_rows)
    neg_cnt = jnp.where(val, -cnt2, 0.0)
    row_s, _, other_s, cnt_s, val_s = jax.lax.sort(
        (row_k, neg_cnt, other, cnt2, val.astype(jnp.int32)), num_keys=2
    )
    is_new = boundaries_from_keys(row_s)
    rank = rank_in_segment(is_new)
    keep = (rank < d) & (val_s > 0)
    flat = jnp.where(keep, row_s * d + rank, n_rows * d)
    nbr_id = jnp.full((n_rows * d + 1,), v, jnp.int32).at[flat].set(
        other_s, mode="drop")[:-1]
    nbr_cnt = jnp.zeros((n_rows * d + 1,), jnp.float32).at[flat].set(
        cnt_s, mode="drop")[:-1]

    is_self = valid & (plo == phi)
    self_row = slot_of[jnp.clip(plo, 0, v - 1)]
    ok_self = is_self & (self_row >= 0)
    self_cnt = jnp.zeros((n_rows + 1,), jnp.float32).at[
        jnp.where(ok_self, self_row, n_rows)
    ].add(jnp.where(ok_self, cnt, 0.0), mode="drop")[:-1]
    return nbr_id.reshape(n_rows, d), nbr_cnt.reshape(n_rows, d), self_cnt


def supernode_total_costs_compact(
    plo, phi, cnt, valid, slot_of, n_rows: int, num_nodes: int,
    sizes: jax.Array, cbar: jax.Array, log2v: jax.Array,
) -> jax.Array:
    """``Cost*_A(S)`` per owned row from the local pair records."""
    na = sizes[jnp.clip(plo, 0, num_nodes - 1)].astype(jnp.float32)
    nb = sizes[jnp.clip(phi, 0, num_nodes - 1)].astype(jnp.float32)
    pi = jnp.where(plo == phi, na * (na - 1.0) * 0.5, na * nb)
    cost = jnp.where(valid, costs.pair_cost_star(cnt, pi, cbar, log2v), 0.0)
    out = jnp.zeros((n_rows + 1,), jnp.float32)
    row_lo = jnp.where(valid, slot_of[jnp.clip(plo, 0, num_nodes - 1)], -1)
    row_hi = jnp.where(valid & (plo != phi),
                       slot_of[jnp.clip(phi, 0, num_nodes - 1)], -1)
    out = out.at[jnp.where(row_lo >= 0, row_lo, n_rows)].add(
        jnp.where(row_lo >= 0, cost, 0.0), mode="drop")
    out = out.at[jnp.where(row_hi >= 0, row_hi, n_rows)].add(
        jnp.where(row_hi >= 0, cost, 0.0), mode="drop")
    return out[:-1]


def build_group_tables(
    pt: PairTable,
    state: SummaryState,
    groups: jax.Array,  # int32[G, C]
    max_neighbors: int,
    union_size: int,
    cbar: jax.Array,
    num_nodes: int,
) -> GroupTables:
    """Assemble the dense union-space operands for every group."""
    v = num_nodes
    d = max_neighbors

    nbr_id, nbr_cnt, self_cnt = build_neighbor_tables(pt, v, d)
    pi = costs.pair_pi(pt, state.size)
    log2v = jnp.log2(jnp.float32(v))
    t_all = costs.supernode_total_costs(pt, pi, cbar, log2v, v)
    return assemble_group_tables(
        nbr_id, nbr_cnt, self_cnt, t_all, state.size, groups,
        row_of_member=None, union_size=union_size, num_nodes=v,
    )


def assemble_group_tables(
    nbr_id: jax.Array,  # [N, D] neighbor *global* ids (V = empty)
    nbr_cnt: jax.Array,  # [N, D]
    self_cnt: jax.Array,  # [N]
    t_all: jax.Array,  # [N]
    sizes: jax.Array,  # [V] global supernode sizes
    groups: jax.Array,  # int32[G, C] *global* member ids (-1 = padding)
    row_of_member,  # int32[V] global id → table row, or None (row = id)
    union_size: int,
    num_nodes: int,
) -> GroupTables:
    """Union-space assembly shared by the local ([V,D] tables) and
    distributed-compact ([N_own,D] tables) paths."""
    v = num_nodes
    g_cnt, c = groups.shape
    u = union_size
    d = nbr_id.shape[-1]

    members = groups
    mvalid = members >= 0
    midx = jnp.where(mvalid, members, 0)
    rows = midx if row_of_member is None else jnp.clip(
        row_of_member[midx], 0, nbr_id.shape[0] - 1)
    n = jnp.where(mvalid, sizes[midx], 0).astype(jnp.float32)
    alive = n > 0
    if row_of_member is not None:
        alive = alive & (row_of_member[midx] >= 0)
        n = jnp.where(alive, n, 0.0)
    s = jnp.where(alive, self_cnt[rows], 0.0)
    t = jnp.where(alive, t_all[rows], 0.0)

    tab_id = jnp.where(alive[..., None], nbr_id[rows], v)  # [G, C, D]
    tab_cnt = jnp.where(alive[..., None], nbr_cnt[rows], 0.0)

    # ---- union space: batched sort along the last axis ------------------
    flat_id = tab_id.reshape(g_cnt, c * d)
    flat_cnt = tab_cnt.reshape(g_cnt, c * d)
    row = jnp.broadcast_to(
        jnp.arange(c, dtype=jnp.int32)[None, :, None], (g_cnt, c, d)
    ).reshape(g_cnt, c * d)
    ids_s, row_s, cnt_s = jax.lax.sort((flat_id, row, flat_cnt), num_keys=1)
    first = jnp.concatenate(
        [jnp.ones((g_cnt, 1), bool), ids_s[:, 1:] != ids_s[:, :-1]], axis=1
    )
    col = jnp.cumsum(first.astype(jnp.int32), axis=1) - 1  # [G, C*D]
    entry_ok = (ids_s < v) & (col < u)

    gi = jnp.broadcast_to(
        jnp.arange(g_cnt, dtype=jnp.int32)[:, None], (g_cnt, c * d)
    )
    col_safe = jnp.where(entry_ok, col, u)  # OOB → dropped
    uid = jnp.full((g_cnt, u + 1), v, jnp.int32)
    uid = uid.at[gi, col_safe].min(jnp.where(entry_ok, ids_s, v))[:, :u]
    m = jnp.zeros((g_cnt, c, u + 1), jnp.float32)
    m = m.at[gi, row_s, col_safe].add(jnp.where(entry_ok, cnt_s, 0.0))[:, :, :u]

    n_u = jnp.where(uid < v, sizes[jnp.minimum(uid, v - 1)], 0).astype(
        jnp.float32
    )

    # member's own column in union space (U = absent)
    eq = (uid[:, None, :] == midx[:, :, None]) & alive[:, :, None]  # [G,C,U]
    found = jnp.any(eq, axis=-1)
    cidx = jnp.where(found, jnp.argmax(eq, axis=-1).astype(jnp.int32), u)

    # within-group pair counts from either row's table (max recovers entries
    # truncated out of one of the two rows)
    cj = jnp.minimum(cidx, u - 1)[:, None, :]  # [G,1,C]
    w1 = jnp.take_along_axis(m, jnp.broadcast_to(cj, (g_cnt, c, c)), axis=2)
    w1 = jnp.where((cidx < u)[:, None, :], w1, 0.0)
    w = jnp.maximum(w1, jnp.swapaxes(w1, 1, 2))

    return GroupTables(m=m, n=n, s=s, t=t, n_u=n_u, cidx=cidx, w=w, members=members)
