"""repro.core — SSumM: sparse summarization of massive graphs (KDD'20).

Vectorized TPU-native implementation (`summarize`) plus the faithful
sequential oracle (`ref_numpy.summarize_ref`). See DESIGN.md §3–§4.
"""

from repro.core.summarize import summarize  # noqa: F401
from repro.core.types import (  # noqa: F401
    Graph,
    SummaryConfig,
    SummaryResult,
    SummaryState,
    init_state,
    make_graph,
)
