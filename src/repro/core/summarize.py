"""SSumM driver (Alg. 1): the public entry point of the core library.

``summarize(src, dst, num_nodes, cfg)`` reproduces Alg. 1:

    1. initialize Ḡ := G
    2. while t ≤ T and Size(Ḡ) > k:  candidate generation → merge → sparsify
    3. if Size(Ḡ) > k: further sparsification (drop superedges by ΔRE_p)

plus one guarded extension (``ensure_budget``, DESIGN.md §4): if after T
iterations even the *membership term* |V|log₂|S| exceeds k (so no amount of
edge-dropping can reach the budget), extra θ=0 merge rounds run until the
budget is reachable — this realizes the paper's "always gives a summary
graph whose size does not exceed a given size" claim for very small k.

The loop itself lives in :class:`repro.core.engine.SummaryEngine`
(DESIGN.md §12), driven here through the single-device
:class:`~repro.core.engine.LocalBackend`: the engine dispatches
``cfg.driver_chunk`` jit-compiled rounds per device round-trip
(``lax.while_loop``) and inspects only scalar metrics on chunk boundaries,
matching the paper's per-iteration check (Alg. 1 line 4) without a
device→host sync every round.
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import LocalBackend, SummaryEngine
from repro.core.types import SummaryConfig, SummaryResult


def summarize(
    src,
    dst,
    num_nodes: int,
    cfg: SummaryConfig = SummaryConfig(),
    collect_history: bool = True,
    *,
    checkpointer=None,
    monitor=None,
    resume: bool = False,
) -> SummaryResult:
    """Run SSumM on an edge list. Returns the summary graph + exact metrics.

    ``checkpointer`` (a :class:`repro.core.engine.EngineCheckpointer`),
    ``monitor`` (a :class:`repro.runtime.straggler.StragglerMonitor`), and
    ``resume`` pass straight through to :meth:`SummaryEngine.run` — the
    crash-safe/preemption-safe path of DESIGN.md §13.
    """
    backend = LocalBackend(src, dst, num_nodes, cfg)
    run = SummaryEngine(backend).run(collect_history=collect_history,
                                     checkpointer=checkpointer,
                                     monitor=monitor, resume=resume)

    pt = run.finalize["pair_table"]
    after = run.finalize["after"]
    keep_np = np.asarray(run.finalize["keep"])
    lo = np.asarray(pt.lo)[keep_np]
    hi = np.asarray(pt.hi)[keep_np]
    w = np.asarray(pt.cnt)[keep_np].astype(np.int64)
    return SummaryResult(
        node2super=np.asarray(run.state.node2super),
        super_size=np.asarray(run.state.size),
        edge_lo=lo,
        edge_hi=hi,
        edge_w=w,
        num_supernodes=int(after["num_supernodes"]),
        num_superedges=int(after["num_superedges"]),
        size_bits=float(after["size_bits"]),
        input_size_bits=float(run.input_size_bits),
        re1=float(after["re1"]),
        re2=float(after["re2"]),
        mdl_cost=float(after["mdl_cost"]),
        iterations_run=run.iterations_run,
        history=run.history,
        chunk_wall_s=run.chunk_wall_s,
        straggler_events=run.straggler_events,
        resumed_from=run.resumed_from,
        checkpoint_saves=run.checkpoint_saves,
        checkpoint_snapshot_wall_s=run.checkpoint_snapshot_wall_s,
    )
