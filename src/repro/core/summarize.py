"""SSumM driver (Alg. 1): the public entry point of the core library.

``summarize(src, dst, num_nodes, cfg)`` reproduces Alg. 1:

    1. initialize Ḡ := G
    2. while t ≤ T and Size(Ḡ) > k:  candidate generation → merge → sparsify
    3. if Size(Ḡ) > k: further sparsification (drop superedges by ΔRE_p)

plus one guarded extension (``ensure_budget``, DESIGN.md §4): if after T
iterations even the *membership term* |V|log₂|S| exceeds k (so no amount of
edge-dropping can reach the budget), extra θ=0 merge rounds run until the
budget is reachable — this realizes the paper's "always gives a summary
graph whose size does not exceed a given size" claim for very small k.

The per-iteration body is one jit-compiled function; the python-level loop
only inspects scalar metrics (size in bits) for the stopping rule, matching
the paper's per-iteration check (Alg. 1 line 4).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, merge, sparsify
from repro.core.types import (
    SummaryConfig,
    SummaryResult,
    SummaryState,
    init_state,
    make_graph,
)


@functools.partial(jax.jit, static_argnames=("cfg", "num_nodes"))
def _iteration(src, dst, state, theta, cfg: SummaryConfig, num_nodes: int):
    return merge.merge_iteration(src, dst, state, cfg, theta)


@functools.partial(jax.jit, static_argnames=("cfg", "num_nodes", "num_edges"))
def _finalize(src, dst, state, k_bits, cfg: SummaryConfig, num_nodes, num_edges):
    pt = costs.build_pair_table(src, dst, state)
    drop, after = sparsify.further_sparsify(
        pt,
        state,
        num_nodes,
        num_edges,
        k_bits,
        cbar_mode=cfg.cbar_mode,
        re_guard=cfg.re_guard,
        error_p=cfg.error_p,
    )
    keep = after["keep"]
    return pt, keep, after


def summarize(
    src,
    dst,
    num_nodes: int,
    cfg: SummaryConfig = SummaryConfig(),
    collect_history: bool = True,
) -> SummaryResult:
    """Run SSumM on an edge list. Returns the summary graph + exact metrics."""
    graph, v = make_graph(src, dst, num_nodes)
    e = graph.num_edges
    size_g = costs.input_size_bits(v, e)
    k_bits = cfg.target_bits(size_g)

    state = init_state(v, cfg.seed)
    history: list[dict] = []
    t_wall = time.perf_counter()

    def run_round(state: SummaryState, theta_val) -> tuple[SummaryState, dict]:
        theta = jnp.asarray(theta_val, jnp.float32)
        new_state, stats = _iteration(graph.src, graph.dst, state, theta, cfg, v)
        return new_state, {k: float(x) for k, x in stats.items()}

    iterations_run = 0
    for t in range(1, cfg.T + 1):
        theta = 1.0 / (1.0 + t) if t < cfg.T else 0.0
        state, stats = run_round(state, theta)
        iterations_run = t
        if collect_history:
            stats["t"] = t
            stats["theta"] = theta
            stats["wall_s"] = time.perf_counter() - t_wall
            history.append(stats)
        if stats["size_bits"] <= k_bits:
            break
        if stats["nmerges"] == 0 and theta == 0.0:
            break  # converged: nothing left that reduces the cost

    # budget-feasibility loop: membership bits |V|log₂|S| must be < k before
    # edge-dropping can finish the job.
    if cfg.ensure_budget:
        for extra in range(cfg.max_extra_iters):
            s_now = int(jnp.sum(state.size > 0))
            membership = v * float(np.log2(max(s_now, 2)))
            if membership <= k_bits or s_now <= 2:
                break
            state, stats = run_round(state, 0.0)
            iterations_run += 1
            if collect_history:
                stats["t"] = iterations_run
                stats["theta"] = 0.0
                stats["wall_s"] = time.perf_counter() - t_wall
                history.append(stats)
            if stats["nmerges"] == 0:
                break

    pt, keep, after = _finalize(graph.src, graph.dst, state, k_bits, cfg, v, e)

    keep_np = np.asarray(keep)
    lo = np.asarray(pt.lo)[keep_np]
    hi = np.asarray(pt.hi)[keep_np]
    w = np.asarray(pt.cnt)[keep_np].astype(np.int64)
    return SummaryResult(
        node2super=np.asarray(state.node2super),
        super_size=np.asarray(state.size),
        edge_lo=lo,
        edge_hi=hi,
        edge_w=w,
        num_supernodes=int(after["num_supernodes"]),
        num_superedges=int(after["num_superedges"]),
        size_bits=float(after["size_bits"]),
        input_size_bits=float(size_g),
        re1=float(after["re1"]),
        re2=float(after["re2"]),
        mdl_cost=float(after["mdl_cost"]),
        iterations_run=iterations_run,
        history=history,
    )
