"""Graph analytics served directly from the summary graph (paper Sect. 1,
benefit (b) "Analyzable": [3, 19, 28] compute adjacency queries, PageRank,
triangle density from summaries without reconstruction).

The reconstruction Ĝ (Eq. 1) is *block-constant*: every node pair (u, v)
with u∈A, v∈B has the same weight σ_AB = w(A,B)/|Π_AB|. All of the queries
below therefore run in O(|S| + |P|) — supernode space — instead of
O(|V| + |E|):

  * ``expected_degree`` — E[deg(u)] under Ĝ.
  * ``adjacency_weight`` — Â_uv, one block-σ lookup.
  * ``pagerank_summary`` — PageRank of Ĝ by power iteration in block space
    (a block-constant vector stays block-constant under Âᵀ D⁻¹, so the
    |V|-dimensional iteration collapses exactly to |S| dimensions).
  * ``triangle_density`` — E[#triangles] of Ĝ from superedge weights.
  * ``cut_weight`` / ``conductance`` — expected cut mass between node
    sets and the conductance of a set, from per-block membership counts
    (the survey's "summary-servable" partition analytics).
  * ``k_hop_size`` — |{v : dist_Ĝ(u, v) ≤ k}|: BFS on the superedge
    support, exact for the block-constant Ĝ because every member of a
    block has the same adjacency (minus the excluded self-pair, which
    never disconnects anything).

All queries consume one shared structure — :class:`BlockSummary`, the
compacted block-space CSR built once per :class:`SummaryResult` by
:func:`build_block_summary` (memoized on the result object; DESIGN.md §14).
The batched device-resident engine in :mod:`repro.core.queries_jax` puts
the *same* arrays on device, so the numpy functions here are its exact
single-query reference.

This module is numpy-only on purpose: it must stay importable without jax
(parse tooling, fixture writers — same constraint as ``repro.graphs.io``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import SummaryResult

# Build counter for the memoization regression test: two successive queries
# against the same SummaryResult must hit the cache, not rebuild the CSR.
BLOCK_BUILDS = 0

_CACHE_ATTR = "_block_summary_cache"


@dataclasses.dataclass(frozen=True)
class BlockSummary:
    """Compacted block-space view of a summary graph (host numpy, float64).

    Supernode ids are compacted to dense block indices ``0..S-1`` (sorted
    original-id order). The superedge set is symmetrized into a CSR whose
    rows AND columns are sorted — so the flattened key ``row·S + col`` is
    globally sorted, which is what the device engine's O(log nnz) pair
    lookup (``jnp.searchsorted``) relies on. Self-superedges appear as
    diagonal entries ``(a, a)``; zero-capacity self pairs (singleton
    blocks) are skipped at build, matching Eq. 1's empty Π.

    ``deg_w[e] = σ_e · (n_col − [col == row])`` is the per-entry expected-
    degree weight: ``deg[a] = Σ_e∈row(a) deg_w[e]`` and one PageRank power
    step is ``new[a] = Σ_e∈row(a) deg_w[e] · share[col(e)]`` — both paths
    (numpy here, jitted row reductions on device) reduce the same entries.
    """

    ids: np.ndarray        # int32[S] original supernode ids (sorted)
    node2block: np.ndarray  # int32[V] dense block index per node
    sizes: np.ndarray      # float64[S] block cardinalities n_a
    indptr: np.ndarray     # int64[S+1] CSR row pointers
    cols: np.ndarray       # int32[nnz] neighbor block (row-major, col-sorted)
    sigma: np.ndarray      # float64[nnz] block-constant weight σ
    deg_w: np.ndarray      # float64[nnz] σ·(n_col − [col==row])
    deg: np.ndarray        # float64[S] expected degree per node of block
    num_nodes: int         # |V|

    @property
    def num_blocks(self) -> int:
        return int(self.ids.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.cols.shape[0])

    @property
    def rows(self) -> np.ndarray:
        """int32[nnz] row index of every CSR entry."""
        return np.repeat(
            np.arange(self.num_blocks, dtype=np.int32),
            np.diff(self.indptr).astype(np.int64),
        )

    def max_row_nnz(self) -> int:
        """Widest CSR row — the device engine's padded-row width D."""
        if self.num_blocks == 0:
            return 0
        return int(np.max(np.diff(self.indptr)))


def build_block_summary(res: SummaryResult) -> BlockSummary:
    """Build (or fetch the memoized) block-space CSR for ``res``.

    O(|P| log |P|) vectorized numpy — no Python loop over superedges. The
    result is cached on the ``SummaryResult`` instance, so query calls
    after the first are pure array lookups.
    """
    cached = getattr(res, _CACHE_ATTR, None)
    if cached is not None:
        return cached
    global BLOCK_BUILDS
    BLOCK_BUILDS += 1

    ids = np.unique(np.asarray(res.node2super)).astype(np.int32)
    s = ids.shape[0]
    node2block = np.searchsorted(ids, np.asarray(res.node2super)).astype(
        np.int32)
    n = np.asarray(res.super_size)[ids].astype(np.float64)

    lo = np.searchsorted(ids, np.asarray(res.edge_lo)).astype(np.int64)
    hi = np.searchsorted(ids, np.asarray(res.edge_hi)).astype(np.int64)
    w = np.asarray(res.edge_w, dtype=np.float64)
    self_e = lo == hi
    # pair capacities |Π_AB| (Eq. 1); zero-capacity self pairs are dropped
    pi = np.where(self_e, n[lo] * (n[lo] - 1.0) / 2.0, n[lo] * n[hi])
    keep = ~self_e | (pi > 0)
    lo, hi, self_e = lo[keep], hi[keep], self_e[keep]
    sig = np.where(pi[keep] > 0, w[keep] / np.maximum(pi[keep], 1.0), 0.0)

    # symmetrize: one CSR entry per direction, self pairs once
    rows = np.concatenate([lo, hi[~self_e]])
    cols = np.concatenate([hi, lo[~self_e]])
    sigs = np.concatenate([sig, sig[~self_e]])
    order = np.lexsort((cols, rows))
    rows, cols, sigs = rows[order], cols[order], sigs[order]

    indptr = np.zeros(s + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    deg_w = sigs * (n[cols] - (cols == rows).astype(np.float64))
    deg = np.zeros(s, dtype=np.float64)
    np.add.at(deg, rows, deg_w)

    bs = BlockSummary(
        ids=ids, node2block=node2block, sizes=n, indptr=indptr,
        cols=cols.astype(np.int32), sigma=sigs, deg_w=deg_w, deg=deg,
        num_nodes=int(np.asarray(res.node2super).shape[0]),
    )
    res.__dict__[_CACHE_ATTR] = bs
    return bs


def _block_weights(res: SummaryResult):
    """Back-compat view of the old tuple API over the shared builder."""
    bs = build_block_summary(res)
    idx = {int(a): i for i, a in enumerate(bs.ids)}
    nbrs = [
        list(zip(bs.cols[bs.indptr[a]:bs.indptr[a + 1]].tolist(),
                 bs.sigma[bs.indptr[a]:bs.indptr[a + 1]].tolist()))
        for a in range(bs.num_blocks)
    ]
    return bs.ids, idx, bs.sizes, nbrs


def expected_degree(res: SummaryResult, u: int) -> float:
    bs = build_block_summary(res)
    return float(bs.deg[bs.node2block[int(u)]])


def adjacency_weight(res: SummaryResult, u: int, v: int) -> float:
    """Â_uv of the reconstructed Ĝ (Eq. 1): the σ of the (block(u),
    block(v)) superedge, 0 on the diagonal and for absent pairs."""
    if int(u) == int(v):
        return 0.0
    bs = build_block_summary(res)
    a = int(bs.node2block[int(u)])
    b = int(bs.node2block[int(v)])
    row = bs.cols[bs.indptr[a]:bs.indptr[a + 1]]
    pos = np.searchsorted(row, b)
    if pos < row.shape[0] and row[pos] == b:
        return float(bs.sigma[bs.indptr[a] + pos])
    return 0.0


def pagerank_blocks(bs: BlockSummary, damping: float = 0.85,
                    iters: int = 50, tol: float = 1e-10) -> np.ndarray:
    """Power iteration in block space: per-node PageRank value of each
    block (float64[S]). The device engine's ``lax.while_loop`` mirrors
    this loop update-for-update, including the early tolerance break."""
    s = bs.num_blocks
    v_total = float(bs.num_nodes)
    rows = bs.rows
    p = np.full(s, 1.0 / v_total)
    for _ in range(iters):
        share = np.where(bs.deg > 0, p / np.maximum(bs.deg, 1e-300), 0.0)
        new = np.zeros(s)
        np.add.at(new, rows, bs.deg_w * share[bs.cols])
        dangling = float(np.sum(np.where(bs.deg <= 0, p * bs.sizes, 0.0)))
        new = (1.0 - damping) / v_total + damping * (new + dangling / v_total)
        if float(np.max(np.abs(new - p))) < tol:
            p = new
            break
        p = new
    return p


def pagerank_summary(res: SummaryResult, damping: float = 0.85,
                     iters: int = 50, tol: float = 1e-10) -> np.ndarray:
    """PageRank of the reconstructed Ĝ, computed in supernode space.

    Returns the per-*node* PageRank vector (length |V|) — node u's value is
    its supernode's block value. Dangling blocks (zero expected degree)
    redistribute uniformly, matching the standard convention.
    """
    bs = build_block_summary(res)
    p = pagerank_blocks(bs, damping=damping, iters=iters, tol=tol)
    return p[bs.node2block]


def triangle_blocks(bs: BlockSummary) -> float:
    """E[#triangles] over strictly-distinct block triples a<b<c on the
    superedge support: Σ σ_ab σ_bc σ_ca n_a n_b n_c."""
    sig = {}
    rows = bs.rows
    for a, b, w in zip(rows, bs.cols, bs.sigma):
        sig[(int(a), int(b))] = float(w)
    total = 0.0
    for a in range(bs.num_blocks):
        for eb in range(int(bs.indptr[a]), int(bs.indptr[a + 1])):
            b = int(bs.cols[eb])
            if b <= a:
                continue
            sab = float(bs.sigma[eb])
            for ec in range(int(bs.indptr[b]), int(bs.indptr[b + 1])):
                c = int(bs.cols[ec])
                if c <= b:
                    continue
                sca = sig.get((c, a))
                if sca is not None:
                    total += (sab * float(bs.sigma[ec]) * sca
                              * bs.sizes[a] * bs.sizes[b] * bs.sizes[c])
    return total


def triangle_density(res: SummaryResult) -> float:
    """E[#triangles] of Ĝ (sum over supernode triples of σ products),
    restricted to the superedge support — O(|P|·deg) like [19]."""
    return triangle_blocks(build_block_summary(res))


# ------------------------------------------------- set / neighborhood queries

def block_counts(bs: BlockSummary, nodes) -> np.ndarray:
    """Per-block membership counts of a node *set* (float64[S]).

    Nodes are deduplicated — the analytics below are set queries, and the
    serving layer packs the same counts, so duplicates never change an
    answer.
    """
    cnt = np.zeros(bs.num_blocks, dtype=np.float64)
    nodes = np.unique(np.asarray(nodes, dtype=np.int64))
    if nodes.size:
        np.add.at(cnt, bs.node2block[nodes], 1.0)
    return cnt


def _cut_from_counts(bs: BlockSummary, c_a: np.ndarray, c_b: np.ndarray,
                     overlap: np.ndarray) -> float:
    """Σ_{u∈A, v∈B, u≠v} Â_uv from per-block counts.

    Every ordered pair inside one block pair is the same σ, so the sum
    collapses to Σ_e σ_e · c_A[row] · c_B[col] over the symmetrized CSR
    (cross pairs appear in both directions, diagonal entries once); the
    ``overlap`` counts subtract the u == v diagonal of same-block pairs,
    which Â zeroes (Eq. 1 reconstructs a simple graph).
    """
    rows = bs.rows
    total = float(np.sum(bs.sigma * c_a[rows] * c_b[bs.cols]))
    diag = rows == bs.cols
    total -= float(np.sum(bs.sigma[diag] * overlap[rows[diag]]))
    return total


def cut_weight(res: SummaryResult, a_nodes, b_nodes) -> float:
    """Expected total edge weight between node sets A and B under Ĝ
    (self-pairs u == v excluded; A and B may overlap)."""
    bs = build_block_summary(res)
    a = np.unique(np.asarray(a_nodes, dtype=np.int64))
    b = np.unique(np.asarray(b_nodes, dtype=np.int64))
    both = np.intersect1d(a, b, assume_unique=True)
    return _cut_from_counts(bs, block_counts(bs, a), block_counts(bs, b),
                            block_counts(bs, both))


def conductance(res: SummaryResult, a_nodes) -> float:
    """φ(A) = cut(A, V∖A) / min(vol(A), vol(V∖A)) on Ĝ, where vol sums
    expected degrees. Degenerate sets (A empty, A = V, or a zero-volume
    side) return 0.0 — there is no cut to normalize."""
    bs = build_block_summary(res)
    c_a = block_counts(bs, a_nodes)
    c_c = bs.sizes - c_a
    vol_a = float(np.sum(c_a * bs.deg))
    vol_c = float(np.sum(c_c * bs.deg))
    denom = min(vol_a, vol_c)
    if denom <= 0.0:
        return 0.0
    cut = _cut_from_counts(bs, c_a, c_c, np.zeros(bs.num_blocks))
    return cut / denom


def k_hop_size(res: SummaryResult, u: int, k: int) -> float:
    """|{v : dist_Ĝ(u, v) ≤ k}| — the size of u's k-hop neighborhood in
    the reconstructed graph, served from the superedge support.

    Block-constant Ĝ makes this exact in block space: every member of a
    block has identical adjacency, so one BFS over blocks answers for
    all |Π| node pairs at once. The frontier after one step from u is
    the support row of u's block (a self-superedge puts u's own block —
    i.e. its *other* members — at distance 1); subsequent steps expand
    over the symmetric support. k = 0 is just {u}.
    """
    bs = build_block_summary(res)
    a0 = int(bs.node2block[int(u)])
    s = bs.num_blocks
    reach = np.zeros(s, dtype=bool)
    if int(k) > 0:
        rows = bs.rows
        live = bs.sigma > 0.0
        lr, lc = rows[live], bs.cols[live]

        def step(r: np.ndarray) -> np.ndarray:
            out = np.zeros(s, dtype=bool)
            np.logical_or.at(out, lr, r[lc])
            return out

        frontier = np.zeros(s, dtype=bool)
        frontier[a0] = True
        reach = step(frontier)
        for _ in range(int(k) - 1):
            grown = reach | step(reach)
            if np.array_equal(grown, reach):
                break
            reach = grown
    members = bs.sizes - (np.arange(s) == a0).astype(np.float64)
    return 1.0 + float(np.sum(np.where(reach, members, 0.0)))
