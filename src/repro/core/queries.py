"""Graph analytics served directly from the summary graph (paper Sect. 1,
benefit (b) "Analyzable": [3, 19, 28] compute adjacency queries, PageRank,
triangle density from summaries without reconstruction).

The reconstruction Ĝ (Eq. 1) is *block-constant*: every node pair (u, v)
with u∈A, v∈B has the same weight σ_AB = w(A,B)/|Π_AB|. All of the queries
below therefore run in O(|S| + |P|) — supernode space — instead of
O(|V| + |E|):

  * ``expected_degree`` — E[deg(u)] under Ĝ.
  * ``pagerank_summary`` — PageRank of Ĝ by power iteration in block space
    (a block-constant vector stays block-constant under Âᵀ D⁻¹, so the
    |V|-dimensional iteration collapses exactly to |S| dimensions).
  * ``triangle_density`` — E[#triangles] of Ĝ from superedge weights.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import SummaryResult


def _block_weights(res: SummaryResult):
    """(ids, sizes, neighbor lists) in compacted supernode space."""
    ids = np.unique(res.node2super)
    idx = {int(a): i for i, a in enumerate(ids)}
    n = res.super_size[ids].astype(np.float64)
    nbrs: list[list[tuple[int, float]]] = [[] for _ in ids]
    for lo, hi, w in zip(res.edge_lo, res.edge_hi, res.edge_w):
        i, j = idx[int(lo)], idx[int(hi)]
        if i == j:
            pi = n[i] * (n[i] - 1) / 2.0
            if pi > 0:
                nbrs[i].append((i, w / pi))
        else:
            pi = n[i] * n[j]
            nbrs[i].append((j, w / pi))
            nbrs[j].append((i, w / pi))
    return ids, idx, n, nbrs


def expected_degree(res: SummaryResult, u: int) -> float:
    ids, idx, n, nbrs = _block_weights(res)
    a = idx[int(res.node2super[u])]
    out = 0.0
    for b, sigma in nbrs[a]:
        out += sigma * (n[b] - 1.0 if b == a else n[b])
    return out


def pagerank_summary(res: SummaryResult, damping: float = 0.85,
                     iters: int = 50, tol: float = 1e-10) -> np.ndarray:
    """PageRank of the reconstructed Ĝ, computed in supernode space.

    Returns the per-*node* PageRank vector (length |V|) — node u's value is
    its supernode's block value. Dangling blocks (zero expected degree)
    redistribute uniformly, matching the standard convention.
    """
    ids, idx, n, nbrs = _block_weights(res)
    v_total = float(res.node2super.shape[0])
    s = len(ids)
    # expected degree per node of each block
    deg = np.zeros(s)
    for a in range(s):
        for b, sigma in nbrs[a]:
            deg[a] += sigma * (n[b] - 1.0 if b == a else n[b])
    p = np.full(s, 1.0 / v_total)  # per-node value, block-constant
    for _ in range(iters):
        # mass leaving each node of block B: p_B / deg_B per unit weight
        share = np.where(deg > 0, p / np.maximum(deg, 1e-300), 0.0)
        new = np.zeros(s)
        for a in range(s):
            acc = 0.0
            for b, sigma in nbrs[a]:
                if b == a:
                    acc += sigma * (n[a] - 1.0) * share[a]
                else:
                    acc += sigma * n[b] * share[b]
            new[a] = acc
        dangling = float(np.sum(np.where(deg <= 0, p * n, 0.0)))
        new = (1.0 - damping) / v_total + damping * (new + dangling / v_total)
        if float(np.max(np.abs(new - p))) < tol:
            p = new
            break
        p = new
    out = np.zeros(int(v_total))
    for a_id, i in idx.items():
        out[res.node2super == a_id] = p[i]
    return out


def triangle_density(res: SummaryResult) -> float:
    """E[#triangles] of Ĝ (sum over supernode triples of σ products),
    restricted to the superedge support — O(|P|·deg) like [19]."""
    ids, idx, n, nbrs = _block_weights(res)
    s = len(ids)
    sig = {}
    for a in range(s):
        for b, w in nbrs[a]:
            sig[(a, b)] = w
    total = 0.0
    for a in range(s):
        for b, sab in nbrs[a]:
            if b <= a:
                continue
            for c, sbc in nbrs[b]:
                if c <= b:
                    continue
                sca = sig.get((c, a))
                if sca is not None:
                    total += sab * sbc * sca * n[a] * n[b] * n[c]
    return total
