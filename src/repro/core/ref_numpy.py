"""Faithful sequential reference implementation of SSumM (Alg. 1 + Alg. 2).

This is the *paper-fidelity oracle*: plain numpy + dicts, structured exactly
like Sect. 3 — shingle-grouped candidate sets, `log₂|C|` random pair
sampling, sequential within-group merging with the skip counter, θ(t)
annealing, selective superedge creation, and the final ΔRE drop phase.
It is O(small-graph) only and exists so that

  * the vectorized TPU implementation can be differentially tested, and
  * the paper's own claims (Fig. 4/5/6/8 trends) can be validated against a
    faithful baseline before any beyond-paper change is measured.

Cost definitions mirror :mod:`repro.core.costs` bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


def _entropy_bits(cnt: float, pi: float) -> float:
    if pi <= 0 or cnt <= 0 or cnt >= pi:
        return 0.0
    s = cnt / pi
    return -pi * (s * math.log2(s) + (1 - s) * math.log2(1 - s))


@dataclasses.dataclass
class RefSummary:
    node2super: np.ndarray
    super_size: np.ndarray
    superedges: dict  # {(lo, hi): weight}
    size_bits: float
    re1: float
    re2: float
    num_supernodes: int
    history: list


class SSumMRef:
    """Sequential SSumM. ``adj[a][b] = |E_ab|`` over supernode ids."""

    def __init__(self, src, dst, num_nodes: int, seed: int = 0,
                 cbar_mode: str = "tight", re_guard: int = 1,
                 group_cap: int = 500):
        self.v = int(num_nodes)
        src = np.asarray(src); dst = np.asarray(dst)
        lo = np.minimum(src, dst); hi = np.maximum(src, dst)
        keep = lo != hi
        pairs = {(int(a), int(b)) for a, b in zip(lo[keep], hi[keep])}
        self.edges = sorted(pairs)
        self.e = len(self.edges)
        self.rng = np.random.default_rng(seed)
        self.cbar_mode = cbar_mode
        self.re_guard = re_guard
        self.group_cap = group_cap
        self.log2v = math.log2(max(self.v, 2))
        self.log2e = math.log2(max(self.e, 2))

        # supernode state
        self.n2s = np.arange(self.v, dtype=np.int64)
        self.size = np.ones(self.v, dtype=np.int64)
        self.members: dict[int, list[int]] = {i: [i] for i in range(self.v)}
        # adjacency between supernodes: cnt[a][b] (a<=b keyed both ways)
        self.adj: dict[int, dict[int, int]] = {i: {} for i in range(self.v)}
        self.node_adj: dict[int, list[int]] = {i: [] for i in range(self.v)}
        for a, b in self.edges:
            self.adj[a][b] = self.adj[a].get(b, 0) + 1
            self.adj[b][a] = self.adj[b].get(a, 0) + 1
            self.node_adj[a].append(b)
            self.node_adj[b].append(a)
        self.self_cnt = np.zeros(self.v, dtype=np.int64)

    # -- cost machinery (Sect. 3.1) ------------------------------------
    def _cbar(self) -> float:
        if self.cbar_mode == "paper":
            return 2 * self.log2v + self.log2e
        s = max(int((self.size > 0).sum()), 2)
        w = max(self._omega_max_estimate(), 2)
        return 2 * math.log2(s) + math.log2(w)

    def _omega_max_estimate(self) -> int:
        w = int(self.self_cnt.max()) if self.v else 0
        for a, nb in self.adj.items():
            if self.size[a] > 0 and nb:
                m = max(nb.values())
                w = max(w, m)
        return max(w, 1)

    def _pi(self, a: int, b: int) -> float:
        if a == b:
            na = float(self.size[a])
            return na * (na - 1) / 2
        return float(self.size[a]) * float(self.size[b])

    def pair_cost(self, cnt: float, pi: float, cbar: float) -> float:
        if cnt <= 0:
            return 0.0
        return min(cbar + _entropy_bits(cnt, pi), 2 * cnt * self.log2v)

    def supernode_cost(self, a: int, cbar: float) -> float:
        tot = self.pair_cost(float(self.self_cnt[a]), self._pi(a, a), cbar)
        for b, cnt in self.adj[a].items():
            tot += self.pair_cost(float(cnt), self._pi(a, b), cbar)
        return tot

    def merged_cost(self, a: int, b: int, cbar: float) -> float:
        """Cost*_{A∪B}(S') — exact union over both neighbor maps."""
        na, nb = float(self.size[a]), float(self.size[b])
        nn = na + nb
        w_ab = self.adj[a].get(b, 0)
        self_cnt = float(self.self_cnt[a] + self.self_cnt[b] + w_ab)
        tot = self.pair_cost(self_cnt, nn * (nn - 1) / 2, cbar)
        nbrs = set(self.adj[a]) | set(self.adj[b])
        nbrs.discard(a); nbrs.discard(b)
        for c in nbrs:
            cnt = self.adj[a].get(c, 0) + self.adj[b].get(c, 0)
            tot += self.pair_cost(float(cnt), nn * float(self.size[c]), cbar)
        return tot

    def relative_reduction(self, a: int, b: int, cbar: float) -> float:
        """Eq. (20)."""
        cost_a = self.supernode_cost(a, cbar)
        cost_b = self.supernode_cost(b, cbar)
        cost_ab = self.pair_cost(float(self.adj[a].get(b, 0)), self._pi(a, b), cbar)
        denom = cost_a + cost_b - cost_ab
        if denom <= 1e-9:
            return -math.inf
        return 1.0 - self.merged_cost(a, b, cbar) / denom

    # -- shingles / candidate sets (Sect. 3.2.2) ------------------------
    def _candidate_sets(self) -> list[list[int]]:
        h = self.rng.permutation(self.v)
        nf = h.copy()
        for a, b in self.edges:
            nf[a] = min(nf[a], h[b])
            nf[b] = min(nf[b], h[a])
        shingle: dict[int, int] = {}
        for sid in np.nonzero(self.size > 0)[0]:
            shingle[int(sid)] = min(int(nf[u]) for u in self.members[int(sid)])
        groups: dict[int, list[int]] = {}
        for sid, f in shingle.items():
            groups.setdefault(f, []).append(sid)
        out: list[list[int]] = []
        for g in groups.values():
            if len(g) <= self.group_cap:
                out.append(g)
            else:  # random split of oversized shingle groups (paper: ≤10
                # recursive re-hash rounds, then random — random directly
                # is the terminal behavior)
                self.rng.shuffle(g)
                for i in range(0, len(g), self.group_cap):
                    out.append(g[i : i + self.group_cap])
        return out

    # -- merging (Alg. 2) ------------------------------------------------
    def _merge(self, a: int, b: int) -> None:
        """Absorb b into a (supernode ids follow the vectorized convention)."""
        if a > b:
            a, b = b, a
        w_ab = self.adj[a].pop(b, 0)
        self.adj[b].pop(a, None)
        self.self_cnt[a] += self.self_cnt[b] + w_ab
        self.self_cnt[b] = 0
        for c, cnt in self.adj[b].items():
            self.adj[c].pop(b, None)
            self.adj[a][c] = self.adj[a].get(c, 0) + cnt
            self.adj[c][a] = self.adj[a][c]
        self.adj[b] = {}
        self.members[a].extend(self.members[b])
        for u in self.members[b]:
            self.n2s[u] = a
        self.members[b] = []
        self.size[a] += self.size[b]
        self.size[b] = 0

    def _process_candidate_set(self, cand: list[int], theta: float) -> int:
        merges = 0
        cand = [c for c in cand if self.size[c] > 0]
        num_skips = 0
        cbar = self._cbar()
        while num_skips < max(math.log2(max(len(cand), 2)), 1):
            alive = [c for c in cand if self.size[c] > 0]
            if len(alive) < 2:
                break
            n_pairs = max(int(math.log2(max(len(alive), 2))), 1)
            best, best_pair = -math.inf, None
            for _ in range(n_pairs):
                i, j = self.rng.choice(len(alive), size=2, replace=False)
                a, b = int(alive[i]), int(alive[j])
                r = self.relative_reduction(a, b, cbar)
                if r > best:
                    best, best_pair = r, (a, b)
            if best_pair is not None and best > theta:
                self._merge(*best_pair)
                merges += 1
                num_skips = 0
                cbar = self._cbar()
            else:
                num_skips += 1
        return merges

    # -- evaluation (Eqs. 2/4/11) ----------------------------------------
    def _keep_decision(self, cnt: float, pi: float, cbar: float) -> bool:
        if cnt <= 0:
            return False
        keep = cbar + _entropy_bits(cnt, pi) < 2 * cnt * self.log2v
        if self.re_guard == 1:
            keep = keep and (2 * cnt / pi - 1 >= 0)
        return keep

    def evaluate(self, extra_drops: set | None = None) -> dict:
        cbar = self._cbar()
        kept: dict[tuple[int, int], int] = {}
        re1 = re2sq = 0.0
        alive = np.nonzero(self.size > 0)[0]
        seen = set()
        all_pairs = []
        for a in alive:
            a = int(a)
            if self.self_cnt[a] > 0:
                all_pairs.append((a, a, float(self.self_cnt[a])))
            for b, cnt in self.adj[a].items():
                if a < b:
                    all_pairs.append((a, b, float(cnt)))
        for a, b, cnt in all_pairs:
            pi = self._pi(a, b)
            # paper P semantics: pairs never adjacent to a merge keep their
            # initial superedge (Alg. 1 line 2); touched pairs are re-decided
            # (Alg. 1 line 7 / Eq. 11 + footnote-3 RE guard).
            touched = self.size[a] > 1 or self.size[b] > 1
            keep = self._keep_decision(cnt, pi, cbar) if touched else True
            if extra_drops and (a, b) in extra_drops:
                keep = False
            if keep:
                kept[(a, b)] = int(cnt)
                sig = cnt / pi
                re1 += 2 * cnt * (1 - sig)
                re2sq += cnt * (1 - sig)
            else:
                re1 += cnt
                re2sq += cnt
            seen.add((a, b))
        s = max(len(alive), 2)
        p = len(kept)
        w_max = max(max(kept.values()), 2) if kept else 2
        size_bits = p * (2 * math.log2(s) + math.log2(w_max)) + self.v * math.log2(s)
        denom = self.v * (self.v - 1)
        return {
            "kept": kept,
            "pairs": all_pairs,
            "size_bits": size_bits,
            "re1": 2 * re1 / denom,
            "re2": math.sqrt(2 * re2sq) / denom,
            "num_supernodes": int(len(alive)),
        }

    # -- driver (Alg. 1) ---------------------------------------------------
    def run(self, k_frac: float = 0.3, big_t: int = 20) -> RefSummary:
        size_g = 2 * self.e * self.log2v
        k_bits = k_frac * size_g
        history = []
        for t in range(1, big_t + 1):
            theta = 1.0 / (1.0 + t) if t < big_t else 0.0
            for cand in self._candidate_sets():
                self._process_candidate_set(cand, theta)
            ev = self.evaluate()
            history.append({"t": t, "size_bits": ev["size_bits"],
                            "re1": ev["re1"], "re2": ev["re2"],
                            "num_supernodes": ev["num_supernodes"]})
            if ev["size_bits"] <= k_bits:
                break
        ev = self.evaluate()
        drops: set = set()
        if ev["size_bits"] > k_bits:
            drops = self._further_sparsify(ev, k_bits)
            ev = self.evaluate(extra_drops=drops)
        return RefSummary(
            node2super=self.n2s.copy(),
            super_size=self.size.copy(),
            superedges=ev["kept"],
            size_bits=ev["size_bits"],
            re1=ev["re1"],
            re2=ev["re2"],
            num_supernodes=ev["num_supernodes"],
            history=history,
        )

    def _further_sparsify(self, ev: dict, k_bits: float) -> set:
        kept = ev["kept"]
        if not kept:
            return set()
        s = max(ev["num_supernodes"], 2)
        w_max = max(max(kept.values()), 2)
        unit = 2 * math.log2(s) + math.log2(w_max)
        xi = math.ceil(max(ev["size_bits"] - k_bits, 0.0) / unit)
        if xi <= 0:
            return set()
        deltas = []
        for (a, b), cnt in kept.items():
            pi = self._pi(a, b)
            deltas.append(((2 * cnt / pi - 1) * cnt, (a, b)))
        deltas.sort(key=lambda x: x[0])
        if xi >= len(deltas):
            return {p for _, p in deltas}
        thr = deltas[xi - 1][0]
        return {p for d, p in deltas if d <= thr}


def summarize_ref(src, dst, num_nodes: int, k_frac: float = 0.3,
                  big_t: int = 20, seed: int = 0, **kw) -> RefSummary:
    return SSumMRef(src, dst, num_nodes, seed=seed, **kw).run(k_frac, big_t)
