"""SummaryEngine: the single owner of Alg. 1, over a pluggable ``Backend``.

Before this module the repo carried three divergent copies of the paper's
merge→sparsify loop (``summarize()`` plus the ``make_distributed_step*``
builders), each re-implementing the θ schedule, stopping rule, budget
feasibility and finalize. The engine collapses them (DESIGN.md §12): it owns

  * the θ schedule — Eq. (21), θ(t) = (1+t)⁻¹ for t < T, 0 at t = T;
  * the stopping rule — Alg. 1 line 4 (``size_bits ≤ k``) plus convergence
    (θ = 0 and no merges accepted);
  * the ``ensure_budget`` feasibility rounds (DESIGN.md §4): extra θ = 0
    merges until the membership term |V|log₂|S| fits under k;
  * finalize — the Sect. 3.2.4 drop-to-k further sparsification,

while a :class:`Backend` supplies the three device-side primitives:

  * ``run_chunk``        — score/merge up to R rounds in one dispatch;
  * ``num_supernodes``   — |S| of a state (feasibility check);
  * ``sparsify_finalize``— the drop-to-k tail + exact Eq. (2)/(4) metrics.

**Chunked, device-resident driver.** ``run_chunk`` executes up to
``cfg.driver_chunk`` rounds inside one ``lax.while_loop`` dispatch: the
stopping predicate is evaluated on device each round, per-round scalar
stats land in an on-device [R]-buffer, and the host syncs only on chunk
boundaries — instead of a full device→host round-trip per iteration.
θ values are precomputed on the host (bit-identical to the historical
per-round python floats) and passed as an f32[R] operand. Because each
round runs exactly the same traced computation as the historical
one-round-per-dispatch driver, metrics are bit-identical for any chunk
size; ``driver_chunk=1`` recovers the historical host-synced driver
(benchmarks/fig8_iterations.py measures the difference).

Backends in-tree: :class:`LocalBackend` below (single device; the engine
behind ``repro.core.summarize``) and
``repro.core.distributed.make_distributed_backend`` (edge-sharded
shard_map, hash- or group-owner pair routing). Streaming summarization and
the query-serving layer plug in the same way: implement the three
primitives, reuse the loop.

**Fault tolerance (DESIGN.md §13).** Everything Alg. 1 needs to continue
from a chunk boundary is one replicated pytree (the ``SummaryState``:
supernode membership, sizes, rng, round counter) plus a small host-side
payload (θ-schedule position ``t_next`` — also the distributed salt
``t0`` —, the stopping flag, budget-loop position, phase marker, history,
and the config/graph fingerprints). :class:`EngineCheckpointer` saves that
through :class:`repro.runtime.checkpoint.CheckpointManager` — async,
atomic, keep-N — at the engine's host-sync points, and
:meth:`SummaryEngine.run` with ``resume=True`` validates the fingerprints
and continues *bit-identically*: each round is the same traced computation
wherever the chunk boundaries fall, so a killed-and-resumed run reproduces
the uninterrupted metrics exactly (``tests/chaos_check.py``). A
:class:`~repro.runtime.elastic.PreemptionGuard` polled at the same sync
points turns SIGTERM/SIGINT into save-and-raise
:class:`~repro.runtime.elastic.Preempted`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, merge, sparsify
from repro.core.types import (
    SummaryConfig,
    SummaryState,
    init_state,
    make_graph,
)
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import Preempted, PreemptionGuard
from repro.runtime.straggler import StragglerMonitor

# Per-round scalar stats of the local backend (fixed key set → fixed-shape
# on-device chunk buffers).
LOCAL_STAT_KEYS = (
    "size_bits",
    "mdl_cost",
    "re1",
    "re2",
    "nmerges",
    "num_supernodes",
    "num_superedges",
    "total_reduction",
)


def theta_schedule_host(t: int, big_t: int) -> float:
    """Eq. (21) on the host — the exact float the driver feeds round ``t``."""
    return 1.0 / (1.0 + t) if t < big_t else 0.0


def global_preempt(local: bool) -> bool:
    """OR a preemption flag across every process in the mesh.

    On a process-spanning mesh (DESIGN.md §15) a SIGTERM lands on each
    process at a *different* loop position; if one process raised
    :class:`Preempted` at sync point ``t`` while another had already
    dispatched chunk ``t+1``, the survivor would hang forever inside a
    collective. Agreeing on the flag at every sync point — itself a tiny
    collective — makes all processes take the same branch. Single-process
    runs return the local flag untouched (no jax call at all).
    """
    if jax.process_count() == 1:
        return bool(local)
    from jax.experimental import multihost_utils

    flags = multihost_utils.process_allgather(np.asarray(bool(local)))
    return bool(np.any(flags))


class Backend(Protocol):
    """Device-side primitives the engine drives (DESIGN.md §12)."""

    cfg: SummaryConfig
    num_nodes: int
    num_edges: int
    stat_keys: tuple[str, ...]

    def input_size_bits(self) -> float:
        """Size(G), Eq. (3) — the quantity budgets are fractions of."""
        ...

    def init(self) -> SummaryState:
        """Ḡ := G (Alg. 1 lines 1–2)."""
        ...

    def run_chunk(
        self, state: SummaryState, thetas: jax.Array, t0: int,
        k_bits: float, limit: int,
    ) -> tuple[SummaryState, dict[str, jax.Array], jax.Array]:
        """Up to ``limit`` merge rounds in one dispatch (``thetas[i]`` is
        round ``t0 + i``'s θ). Returns the new state, per-round stat
        buffers ``{key: f32[R]}``, and the number of rounds executed."""
        ...

    def num_supernodes(self, state: SummaryState) -> int:
        ...

    def sparsify_finalize(
        self, state: SummaryState, k_bits: float, salt: int
    ) -> dict[str, Any]:
        """Sect. 3.2.4 drop-to-k + final metrics; backend-shaped payload."""
        ...

    def state_sharding(self):
        """Target sharding for a restored ``SummaryState`` leaf (or ``None``
        for the default placement) — reshard-on-restore onto the *current*
        mesh, whatever shape the checkpoint was written under."""
        ...


# ---------------------------------------------------------------------------
# Checkpoint/resume of Alg. 1 state (DESIGN.md §13)
# ---------------------------------------------------------------------------


#: SummaryConfig fields excluded from the resume fingerprint: pure execution
#: scheduling with proven bit-identity across values (tests/test_engine.py,
#: tests/dist_check.py) — a run may legitimately resume with a different
#: chunking, e.g. after an elastic re-mesh retuned the dispatch size.
FINGERPRINT_EXEMPT = ("driver_chunk",)


def config_fingerprint(cfg: SummaryConfig) -> dict:
    """The config identity a checkpoint is only resumable under."""
    fp = dataclasses.asdict(cfg)
    for k in FINGERPRINT_EXEMPT:
        fp.pop(k, None)
    return fp


def graph_fingerprint(backend: Backend, extra: dict | None = None) -> dict:
    """Graph identity: |V|, |E| (and caller-supplied provenance, e.g. the
    CSR-cache source stamp). Deliberately mesh-independent — restoring onto
    a different device count is the elastic path, not a mismatch."""
    fp = {"num_nodes": int(backend.num_nodes),
          "num_edges": int(backend.num_edges)}
    if extra:
        fp.update(extra)
    return fp


class FingerprintMismatch(ValueError):
    """A checkpoint was written by a different config or graph."""


@dataclasses.dataclass
class EngineCheckpointer:
    """Chunk-boundary checkpointing policy around a CheckpointManager.

    ``every`` is the save cadence in *completed rounds*, aligned up to the
    engine's host-sync points (chunk boundaries) — with ``driver_chunk=8``
    and ``every=1`` a save still happens only every 8 rounds, because the
    host only holds a consistent state there. ``every <= 0`` disables
    periodic saves; the preemption save and the final ``phase="final"``
    save (merge loop done, only sparsify left) always happen.

    ``guard`` wires preemption in: polled at every sync point, and on a
    pending signal the engine saves synchronously (``wait`` on the async
    writer) and raises :class:`~repro.runtime.elastic.Preempted`.
    """

    manager: CheckpointManager
    every: int = 1
    guard: PreemptionGuard | None = None
    graph_extra: dict | None = None  # provenance merged into the graph fp

    def fingerprints(self, backend: Backend) -> dict:
        return {"config": config_fingerprint(backend.cfg),
                "graph": graph_fingerprint(backend, self.graph_extra)}

    def due(self, completed: int, last_saved: int) -> bool:
        return self.every > 0 and completed - last_saved >= self.every

    def save(self, backend: Backend, state: SummaryState, payload: dict,
             *, sync: bool = False) -> int:
        step = int(payload["t_next"]) - 1  # completed rounds
        # On a process-spanning mesh the Alg. 1 state is replicated, so
        # process 0 writes for everyone (all processes share the directory
        # — DESIGN.md §15); the others still count the save so the
        # `checkpoint_saves` bookkeeping stays identical across processes.
        if jax.process_count() > 1 and jax.process_index() != 0:
            return step
        extra = dict(payload, fingerprints=self.fingerprints(backend))
        self.manager.save_async(step, state, extra)
        if sync:
            self.manager.wait()
        return step

    def restore(self, backend: Backend):
        """Latest committed state or ``None`` (nothing committed yet).

        Returns ``(state, payload, step)``. Validates the config/graph
        fingerprints against ``backend`` and reshards every leaf onto the
        backend's current placement (``state_sharding``) — the 8→4-device
        elastic restore is this one ``device_put``, no resharding pass.
        """
        if self.manager.latest_step() is None:
            return None
        template = backend.init()
        sharding = backend.state_sharding()
        state, step, payload = self.manager.restore(
            template,
            sharding_fn=(None if sharding is None else (lambda _k: sharding)),
        )
        want = self.fingerprints(backend)
        got = payload.get("fingerprints", {})
        for kind in ("config", "graph"):
            if got.get(kind) != want[kind]:
                diff = {
                    k: (got.get(kind, {}).get(k), want[kind][k])
                    for k in set(want[kind]) | set(got.get(kind, {}))
                    if got.get(kind, {}).get(k) != want[kind].get(k)
                }
                raise FingerprintMismatch(
                    f"checkpoint step {step} in {self.manager.dir!r} was "
                    f"written under a different {kind}: "
                    f"{{field: (checkpoint, current)}} = {diff}")
        return state, payload, step

    def preempted(self) -> bool:
        return self.guard is not None and self.guard.preempted


@dataclasses.dataclass
class EngineRun:
    """Everything Alg. 1 produced, before backend-specific result assembly."""

    state: SummaryState
    history: list[dict]
    last_stats: dict | None  # stats of the last merge round (None if T=0)
    iterations_run: int
    input_size_bits: float
    k_bits: float
    finalize: dict[str, Any]  # backend payload from sparsify_finalize
    sparsify_wall_s: float
    # fault-tolerance / observability bookkeeping (DESIGN.md §13)
    chunk_wall_s: list = dataclasses.field(default_factory=list)
    straggler_events: list = dataclasses.field(default_factory=list)
    resumed_from: int | None = None  # checkpoint step this run restarted at
    checkpoint_saves: int = 0
    checkpoint_snapshot_wall_s: float = 0.0  # driver-thread stall, total


class SummaryEngine:
    """Alg. 1 against a :class:`Backend`; one loop for every execution mode."""

    def __init__(self, backend: Backend):
        self.backend = backend
        self.cfg = backend.cfg

    def _should_stop(self, stats: dict, theta: float, k_bits: float) -> bool:
        if stats["size_bits"] <= k_bits:
            return True
        # converged: θ=0 accepts any cost-reducing merge; none left
        return stats["nmerges"] == 0 and theta == 0.0

    def run(self, collect_history: bool = True, *,
            checkpointer: EngineCheckpointer | None = None,
            monitor: StragglerMonitor | None = None,
            resume: bool = False) -> EngineRun:
        """Drive Alg. 1 to the final summary (optionally crash-safe).

        With a ``checkpointer``, the replicated Alg. 1 state plus the
        host-side loop position is saved (async, atomic) at chunk
        boundaries, and ``resume=True`` continues a prior run from its
        latest committed checkpoint — bit-identical to never having
        stopped, because every round is the same traced computation
        regardless of where the chunk boundaries fall. A pending
        preemption signal (``checkpointer.guard``) is honored at the same
        sync points: save synchronously, raise
        :class:`~repro.runtime.elastic.Preempted`.

        ``monitor`` (a :class:`~repro.runtime.straggler.StragglerMonitor`)
        brackets every device dispatch with ``begin_step``/``end_step``;
        flagged events land in ``EngineRun.straggler_events`` and per-chunk
        wall times in ``EngineRun.chunk_wall_s``.
        """
        cfg, backend = self.cfg, self.backend
        size_g = backend.input_size_bits()
        k_bits = cfg.target_bits(size_g)
        chunk = max(1, cfg.driver_chunk)
        ck = checkpointer

        history: list[dict] = []
        chunk_walls: list[float] = []
        last: dict | None = None
        stopped = False
        t = 1  # next round index == the distributed salt t0
        extra_done = 0  # budget-feasibility rounds already run
        phase = "loop"  # "loop" (merge/budget rounds left) | "final"
        resumed_from: int | None = None
        saves = 0
        last_saved = 0

        if resume:
            if ck is None:
                raise ValueError("resume=True requires a checkpointer")
            restored = ck.restore(backend)
            if restored is not None:
                state, payload, resumed_from = restored
                t = int(payload["t_next"])
                stopped = bool(payload["stopped"])
                extra_done = int(payload["extra_done"])
                phase = payload["phase"]
                last = payload["last_stats"]
                last_saved = t - 1
                if collect_history:
                    history = list(payload["history"])
            else:
                state = backend.init()
        else:
            state = backend.init()

        t_wall = time.perf_counter()

        def run_rounds(state, t0: int, limit: int, thetas: list[float]):
            """One device dispatch of ≤ ``limit`` rounds; host-side unpack."""
            th = np.zeros((chunk,), np.float32)
            th[: len(thetas)] = np.asarray(thetas, np.float32)
            if monitor is not None:
                monitor.begin_step()
            t_disp = time.perf_counter()
            state, buf, rounds = backend.run_chunk(
                state, jnp.asarray(th), t0, k_bits, limit
            )
            rounds = int(rounds)
            buf = {k: np.asarray(v) for k, v in buf.items()}
            # the unpack above blocked on the dispatch — time is real work
            chunk_walls.append(time.perf_counter() - t_disp)
            if monitor is not None:
                monitor.end_step(t0)
            rows = [
                {k: float(buf[k][i]) for k in backend.stat_keys}
                for i in range(rounds)
            ]
            return state, rows

        def payload_now() -> dict:
            return {
                "t_next": t, "stopped": stopped, "extra_done": extra_done,
                "phase": phase, "last_stats": last,
                "history": history if collect_history else [],
            }

        def sync_point(state, *, force: bool = False) -> None:
            """Host-sync bookkeeping: periodic save + preemption poll."""
            nonlocal saves, last_saved
            if ck is None:
                return
            preempt = ck.preempted()
            if ck.guard is not None:
                preempt = global_preempt(preempt)
            if force or preempt or ck.due(t - 1, last_saved):
                step = ck.save(backend, state, payload_now(), sync=preempt)
                saves += 1
                last_saved = t - 1
                if preempt:
                    raise Preempted(step)

        while phase == "loop" and t <= cfg.T and not stopped:
            limit = min(chunk, cfg.T - t + 1)
            thetas = [theta_schedule_host(tt, cfg.T)
                      for tt in range(t, t + limit)]
            state, rows = run_rounds(state, t, limit, thetas)
            wall = time.perf_counter() - t_wall
            for i, row in enumerate(rows):
                last = row
                if collect_history:
                    history.append(
                        dict(row, t=t + i, theta=thetas[i], wall_s=wall)
                    )
            t += len(rows)
            last_theta = thetas[len(rows) - 1]
            stopped = self._should_stop(last, last_theta, k_bits)
            sync_point(state)

        # budget-feasibility loop (DESIGN.md §4): membership bits
        # |V|log₂|S| must fit under k before edge-dropping can finish.
        # Every break decision is either re-derivable from the restored
        # state (membership, s_now) or encoded in the checkpoint phase
        # (the nmerges==0 convergence break), so a resumed run walks the
        # exact same extra rounds as an uninterrupted one.
        if cfg.ensure_budget:
            v = backend.num_nodes
            while phase == "loop" and extra_done < cfg.max_extra_iters:
                s_now = backend.num_supernodes(state)
                membership = v * float(np.log2(max(s_now, 2)))
                if membership <= k_bits or s_now <= 2:
                    break
                state, rows = run_rounds(state, t, 1, [0.0])
                last = rows[0]
                if collect_history:
                    history.append(dict(
                        rows[0], t=t, theta=0.0,
                        wall_s=time.perf_counter() - t_wall,
                    ))
                t += 1
                extra_done += 1
                if last["nmerges"] == 0:
                    phase = "final"
                sync_point(state)
                if phase == "final":
                    break
        iterations_run = t - 1

        # merge work is done — one last save so a crash inside the
        # sparsify tail resumes straight to finalize, no re-merging
        if phase != "final":
            phase = "final"
            sync_point(state, force=True)

        t_sp = time.perf_counter()
        finalize = backend.sparsify_finalize(state, k_bits,
                                             iterations_run + 1)
        sparsify_wall_s = time.perf_counter() - t_sp
        snapshot_wall = 0.0
        if ck is not None:
            ck.manager.wait()  # surface async write errors before returning
            snapshot_wall = sum(
                s["snapshot_wall_s"] or 0.0
                for s in ck.manager.save_stats.values())
        return EngineRun(
            state=state,
            history=history,
            last_stats=last,
            iterations_run=iterations_run,
            input_size_bits=size_g,
            k_bits=k_bits,
            finalize=finalize,
            sparsify_wall_s=sparsify_wall_s,
            chunk_wall_s=chunk_walls,
            straggler_events=list(monitor.events) if monitor else [],
            resumed_from=resumed_from,
            checkpoint_saves=saves,
            checkpoint_snapshot_wall_s=snapshot_wall,
        )


# ---------------------------------------------------------------------------
# Local (single-device) backend — the engine behind repro.core.summarize
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _local_chunk(src, dst, state, thetas, k_bits, limit, cfg: SummaryConfig):
    """≤ ``limit`` merge rounds in one ``lax.while_loop`` dispatch."""
    r = thetas.shape[0]
    buf0 = {k: jnp.zeros((r,), jnp.float32) for k in LOCAL_STAT_KEYS}

    def cond(carry):
        i, _state, done, _buf = carry
        return (i < limit) & ~done

    def body(carry):
        i, state, _done, buf = carry
        theta = thetas[i]
        new_state, stats = merge.merge_iteration(src, dst, state, cfg, theta)
        buf = {
            k: buf[k].at[i].set(stats[k].astype(jnp.float32))
            for k in LOCAL_STAT_KEYS
        }
        done = (stats["size_bits"] <= k_bits) | (
            (stats["nmerges"] == 0) & (theta == 0.0)
        )
        return i + 1, new_state, done, buf

    rounds, state, _done, buf = jax.lax.while_loop(
        cond, body, (jnp.int32(0), state, jnp.bool_(False), buf0)
    )
    return state, buf, rounds


@functools.partial(jax.jit, static_argnames=("cfg", "num_nodes", "num_edges"))
def _local_finalize(src, dst, state, k_bits, cfg: SummaryConfig,
                    num_nodes, num_edges):
    pt = costs.build_pair_table(src, dst, state)
    drop, after = sparsify.further_sparsify(
        pt,
        state,
        num_nodes,
        num_edges,
        k_bits,
        cbar_mode=cfg.cbar_mode,
        re_guard=cfg.re_guard,
        error_p=cfg.error_p,
    )
    return pt, after["keep"], after


class LocalBackend:
    """Single-device Alg. 1 primitives over an in-memory edge list."""

    stat_keys = LOCAL_STAT_KEYS

    def __init__(self, src, dst, num_nodes: int, cfg: SummaryConfig):
        self.graph, self.num_nodes = make_graph(src, dst, num_nodes)
        self.num_edges = self.graph.num_edges
        self.cfg = cfg

    def input_size_bits(self) -> float:
        return costs.input_size_bits(self.num_nodes, self.num_edges)

    def init(self) -> SummaryState:
        return init_state(self.num_nodes, self.cfg.seed)

    def run_chunk(self, state, thetas, t0, k_bits, limit):
        del t0  # local rounds draw their randomness from state.rng alone
        return _local_chunk(
            self.graph.src, self.graph.dst, state, thetas,
            jnp.float32(k_bits), jnp.int32(limit), self.cfg,
        )

    def num_supernodes(self, state) -> int:
        return int(jnp.sum(state.size > 0))

    def state_sharding(self):
        return None  # single device: default placement

    def sparsify_finalize(self, state, k_bits, salt) -> dict:
        del salt  # deterministic closed-form drop — no re-randomization
        pt, keep, after = _local_finalize(
            self.graph.src, self.graph.dst, state, k_bits, self.cfg,
            self.num_nodes, self.num_edges,
        )
        return {"pair_table": pt, "keep": keep, "after": after}
