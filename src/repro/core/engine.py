"""SummaryEngine: the single owner of Alg. 1, over a pluggable ``Backend``.

Before this module the repo carried three divergent copies of the paper's
merge→sparsify loop (``summarize()`` plus the ``make_distributed_step*``
builders), each re-implementing the θ schedule, stopping rule, budget
feasibility and finalize. The engine collapses them (DESIGN.md §12): it owns

  * the θ schedule — Eq. (21), θ(t) = (1+t)⁻¹ for t < T, 0 at t = T;
  * the stopping rule — Alg. 1 line 4 (``size_bits ≤ k``) plus convergence
    (θ = 0 and no merges accepted);
  * the ``ensure_budget`` feasibility rounds (DESIGN.md §4): extra θ = 0
    merges until the membership term |V|log₂|S| fits under k;
  * finalize — the Sect. 3.2.4 drop-to-k further sparsification,

while a :class:`Backend` supplies the three device-side primitives:

  * ``run_chunk``        — score/merge up to R rounds in one dispatch;
  * ``num_supernodes``   — |S| of a state (feasibility check);
  * ``sparsify_finalize``— the drop-to-k tail + exact Eq. (2)/(4) metrics.

**Chunked, device-resident driver.** ``run_chunk`` executes up to
``cfg.driver_chunk`` rounds inside one ``lax.while_loop`` dispatch: the
stopping predicate is evaluated on device each round, per-round scalar
stats land in an on-device [R]-buffer, and the host syncs only on chunk
boundaries — instead of a full device→host round-trip per iteration.
θ values are precomputed on the host (bit-identical to the historical
per-round python floats) and passed as an f32[R] operand. Because each
round runs exactly the same traced computation as the historical
one-round-per-dispatch driver, metrics are bit-identical for any chunk
size; ``driver_chunk=1`` recovers the historical host-synced driver
(benchmarks/fig8_iterations.py measures the difference).

Backends in-tree: :class:`LocalBackend` below (single device; the engine
behind ``repro.core.summarize``) and
``repro.core.distributed.make_distributed_backend`` (edge-sharded
shard_map, hash- or group-owner pair routing). Streaming summarization and
the query-serving layer plug in the same way: implement the three
primitives, reuse the loop.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs, merge, sparsify
from repro.core.types import (
    SummaryConfig,
    SummaryState,
    init_state,
    make_graph,
)

# Per-round scalar stats of the local backend (fixed key set → fixed-shape
# on-device chunk buffers).
LOCAL_STAT_KEYS = (
    "size_bits",
    "mdl_cost",
    "re1",
    "re2",
    "nmerges",
    "num_supernodes",
    "num_superedges",
    "total_reduction",
)


def theta_schedule_host(t: int, big_t: int) -> float:
    """Eq. (21) on the host — the exact float the driver feeds round ``t``."""
    return 1.0 / (1.0 + t) if t < big_t else 0.0


class Backend(Protocol):
    """Device-side primitives the engine drives (DESIGN.md §12)."""

    cfg: SummaryConfig
    num_nodes: int
    stat_keys: tuple[str, ...]

    def input_size_bits(self) -> float:
        """Size(G), Eq. (3) — the quantity budgets are fractions of."""
        ...

    def init(self) -> SummaryState:
        """Ḡ := G (Alg. 1 lines 1–2)."""
        ...

    def run_chunk(
        self, state: SummaryState, thetas: jax.Array, t0: int,
        k_bits: float, limit: int,
    ) -> tuple[SummaryState, dict[str, jax.Array], jax.Array]:
        """Up to ``limit`` merge rounds in one dispatch (``thetas[i]`` is
        round ``t0 + i``'s θ). Returns the new state, per-round stat
        buffers ``{key: f32[R]}``, and the number of rounds executed."""
        ...

    def num_supernodes(self, state: SummaryState) -> int:
        ...

    def sparsify_finalize(
        self, state: SummaryState, k_bits: float, salt: int
    ) -> dict[str, Any]:
        """Sect. 3.2.4 drop-to-k + final metrics; backend-shaped payload."""
        ...


@dataclasses.dataclass
class EngineRun:
    """Everything Alg. 1 produced, before backend-specific result assembly."""

    state: SummaryState
    history: list[dict]
    last_stats: dict | None  # stats of the last merge round (None if T=0)
    iterations_run: int
    input_size_bits: float
    k_bits: float
    finalize: dict[str, Any]  # backend payload from sparsify_finalize
    sparsify_wall_s: float


class SummaryEngine:
    """Alg. 1 against a :class:`Backend`; one loop for every execution mode."""

    def __init__(self, backend: Backend):
        self.backend = backend
        self.cfg = backend.cfg

    def _should_stop(self, stats: dict, theta: float, k_bits: float) -> bool:
        if stats["size_bits"] <= k_bits:
            return True
        # converged: θ=0 accepts any cost-reducing merge; none left
        return stats["nmerges"] == 0 and theta == 0.0

    def run(self, collect_history: bool = True) -> EngineRun:
        cfg, backend = self.cfg, self.backend
        size_g = backend.input_size_bits()
        k_bits = cfg.target_bits(size_g)
        state = backend.init()
        history: list[dict] = []
        t_wall = time.perf_counter()
        chunk = max(1, cfg.driver_chunk)

        def run_rounds(state, t0: int, limit: int, thetas: list[float]):
            """One device dispatch of ≤ ``limit`` rounds; host-side unpack."""
            th = np.zeros((chunk,), np.float32)
            th[: len(thetas)] = np.asarray(thetas, np.float32)
            state, buf, rounds = backend.run_chunk(
                state, jnp.asarray(th), t0, k_bits, limit
            )
            rounds = int(rounds)
            buf = {k: np.asarray(v) for k, v in buf.items()}
            rows = [
                {k: float(buf[k][i]) for k in backend.stat_keys}
                for i in range(rounds)
            ]
            return state, rows

        last: dict | None = None
        stopped = False
        t = 1
        while t <= cfg.T and not stopped:
            limit = min(chunk, cfg.T - t + 1)
            thetas = [theta_schedule_host(tt, cfg.T)
                      for tt in range(t, t + limit)]
            state, rows = run_rounds(state, t, limit, thetas)
            wall = time.perf_counter() - t_wall
            for i, row in enumerate(rows):
                last = row
                if collect_history:
                    history.append(
                        dict(row, t=t + i, theta=thetas[i], wall_s=wall)
                    )
            t += len(rows)
            last_theta = thetas[len(rows) - 1]
            stopped = self._should_stop(last, last_theta, k_bits)
        iterations_run = t - 1

        # budget-feasibility loop (DESIGN.md §4): membership bits
        # |V|log₂|S| must fit under k before edge-dropping can finish.
        if cfg.ensure_budget:
            v = backend.num_nodes
            for _extra in range(cfg.max_extra_iters):
                s_now = backend.num_supernodes(state)
                membership = v * float(np.log2(max(s_now, 2)))
                if membership <= k_bits or s_now <= 2:
                    break
                state, rows = run_rounds(state, iterations_run + 1, 1, [0.0])
                iterations_run += 1
                last = rows[0]
                if collect_history:
                    history.append(dict(
                        rows[0], t=iterations_run, theta=0.0,
                        wall_s=time.perf_counter() - t_wall,
                    ))
                if last["nmerges"] == 0:
                    break

        t_sp = time.perf_counter()
        finalize = backend.sparsify_finalize(state, k_bits,
                                             iterations_run + 1)
        sparsify_wall_s = time.perf_counter() - t_sp
        return EngineRun(
            state=state,
            history=history,
            last_stats=last,
            iterations_run=iterations_run,
            input_size_bits=size_g,
            k_bits=k_bits,
            finalize=finalize,
            sparsify_wall_s=sparsify_wall_s,
        )


# ---------------------------------------------------------------------------
# Local (single-device) backend — the engine behind repro.core.summarize
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _local_chunk(src, dst, state, thetas, k_bits, limit, cfg: SummaryConfig):
    """≤ ``limit`` merge rounds in one ``lax.while_loop`` dispatch."""
    r = thetas.shape[0]
    buf0 = {k: jnp.zeros((r,), jnp.float32) for k in LOCAL_STAT_KEYS}

    def cond(carry):
        i, _state, done, _buf = carry
        return (i < limit) & ~done

    def body(carry):
        i, state, _done, buf = carry
        theta = thetas[i]
        new_state, stats = merge.merge_iteration(src, dst, state, cfg, theta)
        buf = {
            k: buf[k].at[i].set(stats[k].astype(jnp.float32))
            for k in LOCAL_STAT_KEYS
        }
        done = (stats["size_bits"] <= k_bits) | (
            (stats["nmerges"] == 0) & (theta == 0.0)
        )
        return i + 1, new_state, done, buf

    rounds, state, _done, buf = jax.lax.while_loop(
        cond, body, (jnp.int32(0), state, jnp.bool_(False), buf0)
    )
    return state, buf, rounds


@functools.partial(jax.jit, static_argnames=("cfg", "num_nodes", "num_edges"))
def _local_finalize(src, dst, state, k_bits, cfg: SummaryConfig,
                    num_nodes, num_edges):
    pt = costs.build_pair_table(src, dst, state)
    drop, after = sparsify.further_sparsify(
        pt,
        state,
        num_nodes,
        num_edges,
        k_bits,
        cbar_mode=cfg.cbar_mode,
        re_guard=cfg.re_guard,
        error_p=cfg.error_p,
    )
    return pt, after["keep"], after


class LocalBackend:
    """Single-device Alg. 1 primitives over an in-memory edge list."""

    stat_keys = LOCAL_STAT_KEYS

    def __init__(self, src, dst, num_nodes: int, cfg: SummaryConfig):
        self.graph, self.num_nodes = make_graph(src, dst, num_nodes)
        self.num_edges = self.graph.num_edges
        self.cfg = cfg

    def input_size_bits(self) -> float:
        return costs.input_size_bits(self.num_nodes, self.num_edges)

    def init(self) -> SummaryState:
        return init_state(self.num_nodes, self.cfg.seed)

    def run_chunk(self, state, thetas, t0, k_bits, limit):
        del t0  # local rounds draw their randomness from state.rng alone
        return _local_chunk(
            self.graph.src, self.graph.dst, state, thetas,
            jnp.float32(k_bits), jnp.int32(limit), self.cfg,
        )

    def num_supernodes(self, state) -> int:
        return int(jnp.sum(state.size > 0))

    def sparsify_finalize(self, state, k_bits, salt) -> dict:
        del salt  # deterministic closed-form drop — no re-randomization
        pt, keep, after = _local_finalize(
            self.graph.src, self.graph.dst, state, k_bits, self.cfg,
            self.num_nodes, self.num_edges,
        )
        return {"pair_table": pt, "keep": keep, "after": after}
