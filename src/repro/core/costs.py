"""MDL cost function of SSumM (Sect. 3.1, Eq. 5–16) in closed, vectorized form.

Key identity exploited throughout (DESIGN.md §4): given a partition ``S``,
the optimal superedge set ``P*(S)`` and every cost/size/error quantity are
closed-form per supernode pair ``{A,B}`` from only two aggregates:

    cnt = |E_AB|   (number of subedges between A and B)
    pi  = |Π_AB|   (number of possible subedges: n_A·n_B, or n_A(n_A-1)/2)

so the whole evaluation reduces to one sort + segment-reduce over the
immutable edge list — no |V|² adjacency matrices anywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import PairTable, SummaryState
from repro.utils import boundaries_from_keys, segment_ids_from_boundaries

# ---------------------------------------------------------------------------
# Entropy encodings (Eq. 9, Eq. 10)
# ---------------------------------------------------------------------------


def entropy_bits(cnt: jax.Array, pi: jax.Array) -> jax.Array:
    """Cost₍₁₎ without C̄: ``-|Π|(σlog₂σ + (1-σ)log₂(1-σ))``, Eq. (9).

    Guarded so that σ∈{0,1} (and Π=0) contribute exactly 0 bits.
    """
    pi = pi.astype(jnp.float32)
    cnt = cnt.astype(jnp.float32)
    safe_pi = jnp.maximum(pi, 1.0)
    sigma = jnp.clip(cnt / safe_pi, 0.0, 1.0)
    # x*log2(x) with the 0·log0 := 0 convention.
    xlogx = jnp.where(sigma > 0.0, sigma * jnp.log2(jnp.maximum(sigma, 1e-38)), 0.0)
    ylogy = jnp.where(
        sigma < 1.0, (1.0 - sigma) * jnp.log2(jnp.maximum(1.0 - sigma, 1e-38)), 0.0
    )
    h = -(xlogx + ylogy)
    return jnp.where((pi > 0.0) & (cnt > 0.0) & (cnt < pi), pi * h, 0.0)


def explicit_bits(cnt: jax.Array, log2v: jax.Array) -> jax.Array:
    """Cost₍₂₎: ``2|E_AB|log₂|V|``, Eq. (10)."""
    return 2.0 * cnt.astype(jnp.float32) * log2v


def pair_cost_star(
    cnt: jax.Array, pi: jax.Array, cbar: jax.Array, log2v: jax.Array
) -> jax.Array:
    """Optimal per-pair description cost: ``min(C̄ + Cost₍₁₎, Cost₍₂₎)`` (Eq. 11/12).

    ``cbar`` is 2log₂|V|+log₂|E| (paper) or the footnote-3 tighter bound.
    Pairs with cnt == 0 cost exactly 0 under either encoding.
    """
    c1 = cbar + entropy_bits(cnt, pi)
    c2 = explicit_bits(cnt, log2v)
    return jnp.where(cnt > 0.0, jnp.minimum(c1, c2), 0.0)


def keep_superedge(
    cnt: jax.Array,
    pi: jax.Array,
    cbar: jax.Array,
    log2v: jax.Array,
    re_guard: int,
) -> jax.Array:
    """Eq. (11) decision: keep {A,B} ∈ P iff entropy encoding is cheaper.

    ``re_guard`` implements footnote 3's "never creates superedges that
    increase RE_p": dropping changes RE₁ by cnt(2σ-1) and RE₂² by cnt·σ
    (footnote 4) — keeping is allowed only when dropping would not shrink
    the error.
    """
    mdl_keep = (cbar + entropy_bits(cnt, pi)) < explicit_bits(cnt, log2v)
    keep = mdl_keep & (cnt > 0.0)
    if re_guard == 1:
        sigma = cnt / jnp.maximum(pi, 1.0)
        keep = keep & (2.0 * sigma - 1.0 >= 0.0)
    # re_guard == 2 never binds: dropping always increases RE₂ (σ>0).
    return keep


# ---------------------------------------------------------------------------
# Pair table: partition → {(A,B) : |E_AB| > 0} via sort + segment reduce
# ---------------------------------------------------------------------------


def build_pair_table(src: jax.Array, dst: jax.Array, state: SummaryState) -> PairTable:
    """Aggregate the edge list into per-supernode-pair subedge counts.

    Sorting uses two int32 keys (``lo``, ``hi``) via ``lax.sort`` so no int64
    composite key is needed (TPU-friendly).
    """
    e = src.shape[0]
    su = state.node2super[src]
    sv = state.node2super[dst]
    lo = jnp.minimum(su, sv)
    hi = jnp.maximum(su, sv)
    lo_s, hi_s = jax.lax.sort((lo, hi), num_keys=2)
    is_new = boundaries_from_keys(lo_s, hi_s)
    pid = segment_ids_from_boundaries(is_new)
    npairs = pid[-1] + 1
    cnt = jax.ops.segment_sum(jnp.ones((e,), jnp.float32), pid, num_segments=e)
    plo = jnp.zeros((e,), jnp.int32).at[pid].max(lo_s)
    phi = jnp.zeros((e,), jnp.int32).at[pid].max(hi_s)
    valid = jnp.arange(e, dtype=jnp.int32) < npairs
    return PairTable(lo=plo, hi=phi, cnt=jnp.where(valid, cnt, 0.0), valid=valid)


def pair_pi(pt: PairTable, size: jax.Array) -> jax.Array:
    """|Π_AB| per pair: n_A·n_B for A≠B, n_A(n_A-1)/2 for the self pair."""
    na = size[pt.lo].astype(jnp.float32)
    nb = size[pt.hi].astype(jnp.float32)
    is_self = pt.lo == pt.hi
    pi = jnp.where(is_self, na * (na - 1.0) * 0.5, na * nb)
    return jnp.where(pt.valid, pi, 0.0)


# ---------------------------------------------------------------------------
# Global quantities: Eq. (3), Eq. (4), Eq. (14), RE_p (Eq. 2 closed form)
# ---------------------------------------------------------------------------


def input_size_bits(num_nodes: int, num_edges: int) -> float:
    """Size(G) = 2|E|log₂|V|, Eq. (3)."""
    return 2.0 * num_edges * float(jnp.log2(jnp.float32(num_nodes)))


def cbar_value(
    mode: str,
    num_nodes: int,
    num_edges: int,
    num_supernodes: jax.Array,
    omega_max: jax.Array,
) -> jax.Array:
    """C̄ — per-superedge model cost. Paper: Eq. (6); tight: footnote 3."""
    if mode == "paper":
        v = jnp.float32(num_nodes)
        e = jnp.float32(num_edges)
        return 2.0 * jnp.log2(v) + jnp.log2(jnp.maximum(e, 2.0))
    s = jnp.maximum(num_supernodes.astype(jnp.float32), 2.0)
    w = jnp.maximum(omega_max.astype(jnp.float32), 2.0)
    return 2.0 * jnp.log2(s) + jnp.log2(w)


def summary_metrics(
    pt: PairTable,
    state: SummaryState,
    num_nodes: int,
    num_edges: int,
    cbar_mode: str = "tight",
    re_guard: int = 1,
    drop_mask: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """All evaluation quantities for the current partition, in one pass.

    **Paper P semantics** (Alg. 1 lines 2 & 7): P is initialized to *all*
    edges, and superedges are re-decided (Eq. 11 + RE guard) only when they
    are adjacent to a newly merged supernode. Since supernode sizes are
    monotone, "was ever re-decided" ≡ ``size[A] > 1 or size[B] > 1`` — so the
    paper's stateful P is recoverable statelessly from the current partition:
    untouched singleton–singleton pairs stay in P unconditionally.

    ``drop_mask`` (bool[E] aligned with ``pt``) marks superedges removed by
    the *further sparsification* phase on top of this.

    Returns exact values of:
      * ``size_bits``  — Eq. (4) with the realized |S|, |P|, ω_max
      * ``mdl_cost``   — Eq. (5) model + data bits over the realized P
      * ``re1``/``re2``— Eq. (2), normalized by |V|(|V|-1) (footnote 5)
      * bookkeeping (num_supernodes, num_superedges, omega_max)
    """
    v = jnp.float32(num_nodes)
    log2v = jnp.log2(v)
    s_count = jnp.sum(state.size > 0).astype(jnp.float32)
    pi = pair_pi(pt, state.size)
    omega_max_all = jnp.max(jnp.where(pt.valid, pt.cnt, 0.0))
    cbar = cbar_value(cbar_mode, num_nodes, num_edges, s_count, omega_max_all)
    touched = (state.size[pt.lo] > 1) | (state.size[pt.hi] > 1)
    decided = keep_superedge(pt.cnt, pi, cbar, log2v, re_guard)
    keep = jnp.where(touched, decided, pt.cnt > 0.0) & pt.valid
    if drop_mask is not None:
        keep = keep & ~drop_mask

    cntk = jnp.where(keep, pt.cnt, 0.0)
    sigma = jnp.where(keep, pt.cnt / jnp.maximum(pi, 1.0), 0.0)

    # --- Eq. (4): realized summary size --------------------------------
    p_count = jnp.sum(keep.astype(jnp.float32))
    omega_max = jnp.max(cntk)
    log2s = jnp.log2(jnp.maximum(s_count, 2.0))
    log2w = jnp.log2(jnp.maximum(omega_max, 2.0))
    size_bits = p_count * (2.0 * log2s + log2w) + v * log2s

    # --- Eq. (14): MDL description cost (upper-bound C̄ per the paper) ---
    log2e = jnp.log2(jnp.maximum(jnp.float32(num_edges), 2.0))
    cbar_paper = 2.0 * log2v + log2e
    kept_bits = cbar_paper + entropy_bits(pt.cnt, pi)
    drop_bits = explicit_bits(pt.cnt, log2v)
    per_pair = jnp.where(keep, kept_bits, jnp.where(pt.valid, drop_bits, 0.0))
    mdl_cost = v * log2v + jnp.sum(per_pair)

    # --- Eq. (2) closed forms (unordered; ×2 for the full matrix) -------
    re1_kept = 2.0 * cntk * (1.0 - sigma)
    re2_kept = cntk * (1.0 - sigma)
    dropped_cnt = jnp.where(pt.valid & ~keep, pt.cnt, 0.0)
    re1_sum = jnp.sum(re1_kept) + jnp.sum(dropped_cnt)
    re2_sq = jnp.sum(re2_kept) + jnp.sum(dropped_cnt)
    denom = v * (v - 1.0)
    re1 = 2.0 * re1_sum / denom
    re2 = jnp.sqrt(2.0 * re2_sq) / denom

    return {
        "size_bits": size_bits,
        "mdl_cost": mdl_cost,
        "re1": re1,
        "re2": re2,
        "num_supernodes": s_count,
        "num_superedges": p_count,
        "omega_max": omega_max,
        "keep": keep,
        "cbar": cbar,
        "membership_bits": v * log2s,
    }


def supernode_total_costs(
    pt: PairTable,
    pi: jax.Array,
    cbar: jax.Array,
    log2v: jax.Array,
    num_nodes: int,
) -> jax.Array:
    """``Cost*_A(S)`` per supernode id (Eq. 16): scatter each pair's optimal
    cost to both endpoints (self pairs once)."""
    cost = jnp.where(pt.valid, pair_cost_star(pt.cnt, pi, cbar, log2v), 0.0)
    out = jnp.zeros((num_nodes,), jnp.float32)
    out = out.at[pt.lo].add(cost)
    is_nonself = pt.lo != pt.hi
    out = out.at[pt.hi].add(jnp.where(is_nonself, cost, 0.0))
    return out
