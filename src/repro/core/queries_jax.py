"""Batched, device-resident summary-query engine (DESIGN.md §14).

The numpy functions in :mod:`repro.core.queries` answer one query at a
time on the host. This module serves the same block-space math at
interactive traffic: the :class:`~repro.core.queries.BlockSummary` CSR is
put on device once (float64 via the ``enable_x64`` scope — queries are
read-mostly and tiny next to the summary, so full precision is free) and
every query kernel is jitted and vectorized over a ``[B]`` request batch:

  * ``expected_degree``  — one gather: ``deg[node2block[u]]``;
  * ``adjacency_weight`` — O(log nnz) lookup of σ via ``searchsorted`` on
    the globally-sorted ``row·S + col`` key;
  * ``pagerank``         — block-space power iteration as a
    ``lax.while_loop`` (computed once, then served as a gather), mirroring
    :func:`repro.core.queries.pagerank_blocks` update-for-update including
    the early tolerance break;
  * ``triangle_density`` — per-row wedge sums over the padded-row layout,
    chunked with ``lax.map`` so memory stays ``O(chunk · D²)``.

Every kernel reduces each CSR row over the same padded ``[S, D]`` layout,
so per-row values are bit-identical between the single-device
:class:`QueryEngine` and the owner-routed :class:`RoutedQueryEngine`: the
routed engine masks each row/query to the device owning its supernode
(``MeshRules.owner`` — the same hash that routes the distributed merge
step's pair exchange) and merges with a ``psum`` of disjoint one-hot
contributions, which is exact in floating point (one real value plus
zeros). This is the first shard-routing tier of SNIPPETS Snippet 3's
fan-out → owner-routed progression: *compute* is routed per owner, the
summary arrays themselves are still replicated per device (the two-tier
memory-partitioned layout is the follow-up, ROADMAP).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.queries import BlockSummary, build_block_summary
from repro.core.types import SummaryResult
from repro.dist import make_rules, shard_map

# Query kinds of the serving wire format (int32 per slot).
KIND_DEGREE = 0
KIND_ADJACENCY = 1
KIND_PAGERANK = 2
KIND_TRIANGLE = 3
KIND_NAMES = {
    "degree": KIND_DEGREE,
    "adjacency": KIND_ADJACENCY,
    "pagerank": KIND_PAGERANK,
    "triangle": KIND_TRIANGLE,
}
# kinds with no per-node target: answered by (routed to) device 0
_GLOBAL_KINDS = (KIND_TRIANGLE,)


@dataclasses.dataclass(frozen=True)
class DeviceBlocks:
    """The BlockSummary arrays on device (float64), plus static shape meta.

    ``pad_*`` is the row-major padded layout ``[S, D]`` (D = widest CSR
    row, at least 1): entry ``[a, j]`` is row a's j-th neighbor, padding
    has ``pad_cols == -1`` and zero σ/deg_w so masked reductions are
    exact. ``key = row·S + col`` over the flat entries is globally sorted
    (CSR rows and columns both sorted), enabling binary-search pair
    lookups.
    """

    node2block: jax.Array  # int32[V]
    sizes: jax.Array       # float64[S]
    deg: jax.Array         # float64[S]
    key: jax.Array         # int64[nnz] sorted row·S + col
    sigma: jax.Array       # float64[nnz] (key order)
    pad_cols: jax.Array    # int32[S, D] (-1 padding)
    pad_sigma: jax.Array   # float64[S, D]
    pad_degw: jax.Array    # float64[S, D]
    s: int                 # static |S|
    d: int                 # static padded row width
    nnz: int               # static superedge-entry count
    num_nodes: int         # static |V|


jax.tree_util.register_pytree_node(
    DeviceBlocks,
    lambda b: ((b.node2block, b.sizes, b.deg, b.key, b.sigma, b.pad_cols,
                b.pad_sigma, b.pad_degw),
               (b.s, b.d, b.nnz, b.num_nodes)),
    lambda meta, leaves: DeviceBlocks(*leaves, *meta),
)


def device_blocks(bs: BlockSummary) -> DeviceBlocks:
    """Put a host BlockSummary on device (call under ``enable_x64``)."""
    s, nnz = bs.num_blocks, bs.nnz
    d = max(1, bs.max_row_nnz())
    rows = bs.rows.astype(np.int64)
    offs = np.arange(nnz, dtype=np.int64) - bs.indptr[rows]
    pad_cols = np.full((s, d), -1, dtype=np.int32)
    pad_sigma = np.zeros((s, d), dtype=np.float64)
    pad_degw = np.zeros((s, d), dtype=np.float64)
    if nnz:
        pad_cols[rows, offs] = bs.cols
        pad_sigma[rows, offs] = bs.sigma
        pad_degw[rows, offs] = bs.deg_w
    return DeviceBlocks(
        node2block=jnp.asarray(bs.node2block, jnp.int32),
        sizes=jnp.asarray(bs.sizes, jnp.float64),
        deg=jnp.asarray(bs.deg, jnp.float64),
        key=jnp.asarray(rows * s + bs.cols, jnp.int64),
        sigma=jnp.asarray(bs.sigma, jnp.float64),
        pad_cols=jnp.asarray(pad_cols),
        pad_sigma=jnp.asarray(pad_sigma),
        pad_degw=jnp.asarray(pad_degw),
        s=s, d=d, nnz=nnz, num_nodes=bs.num_nodes,
    )


# --------------------------------------------------------------- kernels
# Pure functions of (DeviceBlocks, batch arrays); shared verbatim by the
# single-device and routed engines so per-row/per-query float values are
# identical on both paths.

def degree_kernel(dev: DeviceBlocks, u: jax.Array) -> jax.Array:
    return dev.deg[dev.node2block[u]]


def adjacency_kernel(dev: DeviceBlocks, u: jax.Array,
                     v: jax.Array) -> jax.Array:
    if dev.nnz == 0:
        return jnp.zeros(u.shape, jnp.float64)
    a = dev.node2block[u].astype(jnp.int64)
    b = dev.node2block[v].astype(jnp.int64)
    qk = a * dev.s + b
    pos = jnp.clip(jnp.searchsorted(dev.key, qk), 0, dev.nnz - 1)
    sig = jnp.where(dev.key[pos] == qk, dev.sigma[pos], 0.0)
    return jnp.where(u == v, 0.0, sig)


def pagerank_row_sums(dev: DeviceBlocks, share: jax.Array) -> jax.Array:
    """Σ_e∈row deg_w[e]·share[col(e)] for every row — the power-step row
    reduction (padding contributes exact zeros)."""
    gathered = share[jnp.clip(dev.pad_cols, 0, max(dev.s - 1, 0))]
    return jnp.sum(dev.pad_degw * gathered, axis=-1)


def pagerank_update(dev: DeviceBlocks, p: jax.Array, new_rows: jax.Array,
                    damping: float) -> tuple[jax.Array, jax.Array]:
    """Damping + dangling redistribution + tolerance residual (replicated
    math: identical on every device from replicated ``p``/``new_rows``)."""
    vt = float(dev.num_nodes)
    dangling = jnp.sum(jnp.where(dev.deg <= 0, p * dev.sizes, 0.0))
    new = (1.0 - damping) / vt + damping * (new_rows + dangling / vt)
    return new, jnp.max(jnp.abs(new - p))


def triangle_rows(dev: DeviceBlocks, row_chunk: int) -> jax.Array:
    """Per-row triangle mass tri[a] = Σ_{b>a} σ_ab n_a n_b Σ_{c>b} σ_bc
    σ_ca n_c (float64[S]); total = tri.sum(). Chunked over rows so the
    [chunk, D, D] wedge tensor bounds memory; chunking never changes a
    row's value, so any chunk size yields identical per-row floats."""
    s, d = dev.s, dev.d
    if dev.nnz == 0:
        return jnp.zeros((s,), jnp.float64)
    chunk = max(1, min(row_chunk, s))
    n_chunks = -(-s // chunk)
    row_ids = jnp.arange(n_chunks * chunk, dtype=jnp.int32)
    row_ids = row_ids.reshape(n_chunks, chunk)

    def one_chunk(rows):
        live = rows < s
        a = jnp.clip(rows, 0, s - 1)
        b = dev.pad_cols[a]                                    # [R, D]
        sab = dev.pad_sigma[a]
        mask_b = (b > a[:, None]) & live[:, None]
        bc = jnp.clip(b, 0, s - 1)
        c = dev.pad_cols[bc]                                   # [R, D, D]
        sbc = dev.pad_sigma[bc]
        mask_c = (c >= 0) & (c > b[:, :, None]) & mask_b[:, :, None]
        qk = (jnp.clip(c, 0, s - 1).astype(jnp.int64) * s
              + a[:, None, None].astype(jnp.int64))
        pos = jnp.clip(jnp.searchsorted(dev.key, qk.ravel()),
                       0, dev.nnz - 1).reshape(qk.shape)
        sca = jnp.where(mask_c & (dev.key[pos] == qk), dev.sigma[pos], 0.0)
        nc = dev.sizes[jnp.clip(c, 0, s - 1)]
        inner = jnp.sum(jnp.where(mask_c, sbc * sca * nc, 0.0), axis=-1)
        w = jnp.where(
            mask_b,
            sab * inner * dev.sizes[a][:, None]
            * dev.sizes[jnp.clip(b, 0, s - 1)],
            0.0,
        )
        return jnp.sum(w, axis=-1)                             # [R]

    tri = jax.lax.map(one_chunk, row_ids).reshape(-1)
    return tri[:s]


def answer_kernel(dev: DeviceBlocks, kinds, u, v, pr_blocks, tri) -> jax.Array:
    """One fused batched dispatch: per-slot answer selected by kind."""
    deg = degree_kernel(dev, u)
    adj = adjacency_kernel(dev, u, v)
    prq = pr_blocks[dev.node2block[u]]
    tri_b = jnp.broadcast_to(tri, kinds.shape)
    return jnp.select(
        [kinds == KIND_DEGREE, kinds == KIND_ADJACENCY,
         kinds == KIND_PAGERANK, kinds == KIND_TRIANGLE],
        [deg, adj, prq, tri_b], 0.0)


def _pagerank_while(dev: DeviceBlocks, damping: float, iters: int,
                    tol: float, row_sums_fn) -> jax.Array:
    """The shared power-iteration loop; ``row_sums_fn`` is the only part
    that differs between the local and routed engines."""
    vt = float(dev.num_nodes)
    p0 = jnp.full((dev.s,), 1.0 / vt, jnp.float64)

    def cond(carry):
        _, i, done = carry
        return (i < iters) & ~done

    def body(carry):
        p, i, _ = carry
        share = jnp.where(dev.deg > 0, p / jnp.maximum(dev.deg, 1e-300),
                          0.0)
        new, resid = pagerank_update(dev, p, row_sums_fn(share), damping)
        return new, i + 1, resid < tol

    p, _, _ = jax.lax.while_loop(
        cond, body, (p0, jnp.int32(0), jnp.bool_(False)))
    return p


class QueryEngine:
    """Single-device batched query engine over one summary.

    Shapes are static per engine (one compilation per summary + batch
    size, amortized over the serving lifetime). PageRank and triangle
    density are computed lazily on first use and then served as a gather /
    a broadcast scalar.
    """

    def __init__(self, summary: SummaryResult | BlockSummary, *,
                 damping: float = 0.85, pagerank_iters: int = 50,
                 pagerank_tol: float = 1e-10, triangle_row_chunk: int = 64):
        self.bs = (summary if isinstance(summary, BlockSummary)
                   else build_block_summary(summary))
        self.damping = damping
        self.pagerank_iters = pagerank_iters
        self.pagerank_tol = pagerank_tol
        self.triangle_row_chunk = triangle_row_chunk
        self._pr_blocks = None
        self._tri = None
        with enable_x64():
            self.dev = device_blocks(self.bs)
            self._degree = jax.jit(degree_kernel)
            self._adjacency = jax.jit(adjacency_kernel)
            self._answer = jax.jit(answer_kernel)
            self._pagerank = jax.jit(
                lambda dev: _pagerank_while(
                    dev, damping, pagerank_iters, pagerank_tol,
                    lambda share: pagerank_row_sums(dev, share)))
            self._triangle = jax.jit(
                lambda dev: jnp.sum(triangle_rows(dev, triangle_row_chunk)))

    # ------------------------------------------------ lazy global queries
    def pagerank_blocks(self) -> jax.Array:
        if self._pr_blocks is None:
            with enable_x64():
                self._pr_blocks = self._pagerank(self.dev)
        return self._pr_blocks

    def triangle_density(self) -> float:
        if self._tri is None:
            with enable_x64():
                self._tri = self._triangle(self.dev)
        return float(self._tri)

    def pagerank_nodes(self, u) -> np.ndarray:
        pr = self.pagerank_blocks()
        with enable_x64():
            out = pr[self.dev.node2block[jnp.asarray(u, jnp.int32)]]
        return np.asarray(out)

    # --------------------------------------------------- batched queries
    def expected_degree(self, u) -> np.ndarray:
        with enable_x64():
            return np.asarray(
                self._degree(self.dev, jnp.asarray(u, jnp.int32)))

    def adjacency_weight(self, u, v) -> np.ndarray:
        with enable_x64():
            return np.asarray(self._adjacency(
                self.dev, jnp.asarray(u, jnp.int32),
                jnp.asarray(v, jnp.int32)))

    def answer_batch(self, kinds, u, v) -> np.ndarray:
        """Mixed-kind batch: ``kinds``/``u``/``v`` are int32[B]; returns
        float64[B]. The global-query inputs (PageRank vector, triangle
        scalar) are materialized only if the batch asks for them."""
        kinds = np.asarray(kinds, np.int32)
        pr = (self.pagerank_blocks() if (kinds == KIND_PAGERANK).any()
              else None)
        tri = (self.triangle_density() if (kinds == KIND_TRIANGLE).any()
               else 0.0)
        with enable_x64():
            if pr is None:
                pr = jnp.zeros((self.dev.s,), jnp.float64)
            return np.asarray(self._answer(
                self.dev, jnp.asarray(kinds), jnp.asarray(u, jnp.int32),
                jnp.asarray(v, jnp.int32), pr,
                jnp.asarray(tri, jnp.float64)))


class RoutedQueryEngine:
    """Owner-routed multi-device engine: same kernels, psum'd merge.

    Each supernode (block) is owned by ``MeshRules.owner(id, salt)`` — the
    re-drawable hash the distributed merge step already routes pairs with,
    so tooling that predicts record placement agrees across subsystems.
    Per-node queries are answered only by the owner of the target's block;
    global queries (PageRank rows, triangle rows) are computed per owned
    row and merged with a psum of disjoint contributions — exact, and
    bit-identical to :class:`QueryEngine` because every row reduces the
    same padded layout in the same order (tests/query_serve_check.py).

    A mesh change (elastic shrink/grow) is a routing-table rebuild:
    construct a new engine on the survivor mesh — the owner hash only
    depends on device *count* and salt.
    """

    def __init__(self, summary: SummaryResult | BlockSummary, mesh, *,
                 salt: int = 0, damping: float = 0.85,
                 pagerank_iters: int = 50, pagerank_tol: float = 1e-10,
                 triangle_row_chunk: int = 64):
        self.bs = (summary if isinstance(summary, BlockSummary)
                   else build_block_summary(summary))
        self.mesh = mesh
        self.rules = make_rules(mesh, "summarize")
        self.salt = salt
        self.axis_names = tuple(mesh.axis_names)
        self._pr_blocks = None
        self._tri = None
        axis_names = self.axis_names
        rep = self.rules.replicated

        with enable_x64():
            self.dev = device_blocks(self.bs)
            # routing table: block index -> owning device (host-built once;
            # rebuilt by constructing a new engine after a re-mesh)
            self.block_owner = jnp.asarray(np.asarray(self.rules.owner(
                jnp.asarray(self.bs.ids, jnp.int32),
                jnp.uint32(salt))), jnp.int32)

            def my_device():
                return jax.lax.axis_index(axis_names).astype(jnp.int32)

            def routed_rows(x_rows, owner):
                """Keep rows this device owns, psum the one-hot merge."""
                mine = owner == my_device()
                return jax.lax.psum(jnp.where(mine, x_rows, 0.0),
                                    axis_names)

            def pr_body(dev, owner):
                return _pagerank_while(
                    dev, damping, pagerank_iters, pagerank_tol,
                    lambda share: routed_rows(
                        pagerank_row_sums(dev, share), owner))

            self._pagerank = jax.jit(shard_map(
                pr_body, mesh=mesh, in_specs=(rep, rep), out_specs=rep,
                check_vma=False))

            def tri_body(dev, owner):
                tri = routed_rows(triangle_rows(dev, triangle_row_chunk),
                                  owner)
                return jnp.sum(tri)

            self._triangle = jax.jit(shard_map(
                tri_body, mesh=mesh, in_specs=(rep, rep), out_specs=rep,
                check_vma=False))

            def answer_body(dev, owner, kinds, u, v, pr_blocks, tri):
                ans = answer_kernel(dev, kinds, u, v, pr_blocks, tri)
                is_global = jnp.zeros(kinds.shape, bool)
                for k in _GLOBAL_KINDS:
                    is_global |= kinds == k
                target = owner[dev.node2block[u]]
                mine = jnp.where(is_global, my_device() == 0,
                                 target == my_device())
                return jax.lax.psum(jnp.where(mine, ans, 0.0), axis_names)

            self._answer = jax.jit(shard_map(
                answer_body, mesh=mesh, in_specs=(rep,) * 7,
                out_specs=rep, check_vma=False))

    def owner_counts(self) -> np.ndarray:
        """Blocks per owning device — the routing-table histogram."""
        return np.bincount(np.asarray(self.block_owner),
                           minlength=self.rules.n_devices)

    def pagerank_blocks(self) -> jax.Array:
        if self._pr_blocks is None:
            with enable_x64(), self.mesh:
                self._pr_blocks = self._pagerank(self.dev,
                                                 self.block_owner)
        return self._pr_blocks

    def pagerank_nodes(self, u) -> np.ndarray:
        pr = self.pagerank_blocks()
        with enable_x64():
            out = pr[self.dev.node2block[jnp.asarray(u, jnp.int32)]]
        return np.asarray(out)

    def triangle_density(self) -> float:
        if self._tri is None:
            with enable_x64(), self.mesh:
                self._tri = self._triangle(self.dev, self.block_owner)
        return float(self._tri)

    def answer_batch(self, kinds, u, v) -> np.ndarray:
        kinds = np.asarray(kinds, np.int32)
        pr = (self.pagerank_blocks() if (kinds == KIND_PAGERANK).any()
              else None)
        tri = (self.triangle_density() if (kinds == KIND_TRIANGLE).any()
               else 0.0)
        with enable_x64(), self.mesh:
            if pr is None:
                pr = jnp.zeros((self.dev.s,), jnp.float64)
            return np.asarray(self._answer(
                self.dev, self.block_owner, jnp.asarray(kinds),
                jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                pr, jnp.asarray(tri, jnp.float64)))
