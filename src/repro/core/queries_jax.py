"""Batched, device-resident summary-query engine (DESIGN.md §14).

The numpy functions in :mod:`repro.core.queries` answer one query at a
time on the host. This module serves the same block-space math at
interactive traffic: the :class:`~repro.core.queries.BlockSummary` CSR is
put on device once (float64 via the ``enable_x64`` scope — queries are
read-mostly and tiny next to the summary, so full precision is free) and
every query kernel is jitted and vectorized over a ``[B]`` request batch:

  * ``expected_degree``  — one gather: ``deg[node2block[u]]``;
  * ``adjacency_weight`` — O(log nnz) lookup of σ via ``searchsorted`` on
    the globally-sorted ``row·S + col`` key;
  * ``pagerank``         — block-space power iteration as a
    ``lax.while_loop`` (computed once, then served as a gather), mirroring
    :func:`repro.core.queries.pagerank_blocks` update-for-update including
    the early tolerance break;
  * ``triangle_density`` — per-row wedge sums over the padded-row layout,
    chunked with ``lax.map`` so memory stays ``O(chunk · D²)``;
  * ``cut_weight`` / ``conductance`` — node sets packed to per-block count
    rows on the host, reduced as per-row cut contributions;
  * ``k_hop_size`` — BFS fixpoint on superedge support in block space
    (exact for the block-constant Ĝ).

Every kernel reduces each CSR row over the same padded ``[S, D]`` layout,
so per-row values are bit-identical between the single-device
:class:`QueryEngine` and the owner-routed :class:`RoutedQueryEngine`: the
routed engine masks each row/query to the device owning its supernode
(``MeshRules.owner`` — the same hash that routes the distributed merge
step's pair exchange) and merges with a ``psum`` of disjoint one-hot
contributions, which is exact in floating point (one real value plus
zeros). This is the shard-routing tier of SNIPPETS Snippet 3's fan-out →
owner-routed progression: *compute* is routed per owner, the summary
arrays themselves are still replicated per device.

:class:`PartitionedQueryEngine` is the second, memory-partitioned tier
(DESIGN.md §16): each device holds only its owned rows of the padded CSR
plus precomputed halo tables; cross-device lookups go through a per-step
all-gather of the owned value slab (PageRank shares) or resident halo row
copies (triangle wedges), with a second-hop all-gather fallback for rows
denser than ``dense_row_nnz``. Answers stay bit-identical to both
replicated tiers because per-row reductions and their merge order are
unchanged — only row *storage* moves.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.queries import BlockSummary, build_block_summary
from repro.core.types import SummaryResult
from repro.dist import make_rules, shard_map

# Query kinds of the serving wire format (int32 per slot).
KIND_DEGREE = 0
KIND_ADJACENCY = 1
KIND_PAGERANK = 2
KIND_TRIANGLE = 3
KIND_KHOP = 4          # u = target node, v = hop count k
KIND_CUT = 5           # node sets A/B arrive as per-block count rows
KIND_CONDUCTANCE = 6   # node set A as count row; complement derived
KIND_NAMES = {
    "degree": KIND_DEGREE,
    "adjacency": KIND_ADJACENCY,
    "pagerank": KIND_PAGERANK,
    "triangle": KIND_TRIANGLE,
    "khop": KIND_KHOP,
    "cut": KIND_CUT,
    "conductance": KIND_CONDUCTANCE,
}
# kinds with no per-node target: answered by (routed to) device 0
_GLOBAL_KINDS = (KIND_TRIANGLE, KIND_CUT, KIND_CONDUCTANCE)
# kinds dispatched through the extended analytics kernel (set counts /
# BFS inputs) rather than the point-query fast path
_ANALYTIC_KINDS = (KIND_KHOP, KIND_CUT, KIND_CONDUCTANCE)
# kinds whose requests carry node sets (packed to count rows on the host)
_SET_KINDS = (KIND_CUT, KIND_CONDUCTANCE)


@dataclasses.dataclass(frozen=True)
class DeviceBlocks:
    """The BlockSummary arrays on device (float64), plus static shape meta.

    ``pad_*`` is the row-major padded layout ``[S, D]`` (D = widest CSR
    row, at least 1): entry ``[a, j]`` is row a's j-th neighbor, padding
    has ``pad_cols == -1`` and zero σ/deg_w so masked reductions are
    exact. ``key = row·S + col`` over the flat entries is globally sorted
    (CSR rows and columns both sorted), enabling binary-search pair
    lookups.
    """

    node2block: jax.Array  # int32[V]
    sizes: jax.Array       # float64[S]
    deg: jax.Array         # float64[S]
    key: jax.Array         # int64[nnz] sorted row·S + col
    sigma: jax.Array       # float64[nnz] (key order)
    pad_cols: jax.Array    # int32[S, D] (-1 padding)
    pad_sigma: jax.Array   # float64[S, D]
    pad_degw: jax.Array    # float64[S, D]
    s: int                 # static |S|
    d: int                 # static padded row width
    nnz: int               # static superedge-entry count
    num_nodes: int         # static |V|


jax.tree_util.register_pytree_node(
    DeviceBlocks,
    lambda b: ((b.node2block, b.sizes, b.deg, b.key, b.sigma, b.pad_cols,
                b.pad_sigma, b.pad_degw),
               (b.s, b.d, b.nnz, b.num_nodes)),
    lambda meta, leaves: DeviceBlocks(*leaves, *meta),
)


def host_padded_rows(bs: BlockSummary):
    """The padded ``[S, D]`` row layout as host numpy arrays.

    Shared by :func:`device_blocks` (replicated tiers) and
    :func:`build_partition_tables` (partitioned tier) so both tiers pad
    rows identically — a prerequisite for bit-identical row reductions.
    Returns ``(pad_cols i32, pad_sigma f64, pad_degw f64)``.
    """
    s, nnz = bs.num_blocks, bs.nnz
    d = max(1, bs.max_row_nnz())
    rows = bs.rows.astype(np.int64)
    offs = np.arange(nnz, dtype=np.int64) - bs.indptr[rows]
    pad_cols = np.full((s, d), -1, dtype=np.int32)
    pad_sigma = np.zeros((s, d), dtype=np.float64)
    pad_degw = np.zeros((s, d), dtype=np.float64)
    if nnz:
        pad_cols[rows, offs] = bs.cols
        pad_sigma[rows, offs] = bs.sigma
        pad_degw[rows, offs] = bs.deg_w
    return pad_cols, pad_sigma, pad_degw


def device_blocks(bs: BlockSummary) -> DeviceBlocks:
    """Put a host BlockSummary on device (call under ``enable_x64``)."""
    s, nnz = bs.num_blocks, bs.nnz
    d = max(1, bs.max_row_nnz())
    rows = bs.rows.astype(np.int64)
    pad_cols, pad_sigma, pad_degw = host_padded_rows(bs)
    return DeviceBlocks(
        node2block=jnp.asarray(bs.node2block, jnp.int32),
        sizes=jnp.asarray(bs.sizes, jnp.float64),
        deg=jnp.asarray(bs.deg, jnp.float64),
        key=jnp.asarray(rows * s + bs.cols, jnp.int64),
        sigma=jnp.asarray(bs.sigma, jnp.float64),
        pad_cols=jnp.asarray(pad_cols),
        pad_sigma=jnp.asarray(pad_sigma),
        pad_degw=jnp.asarray(pad_degw),
        s=s, d=d, nnz=nnz, num_nodes=bs.num_nodes,
    )


# --------------------------------------------------------------- kernels
# Pure functions of (DeviceBlocks, batch arrays); shared verbatim by the
# single-device and routed engines so per-row/per-query float values are
# identical on both paths.

def degree_kernel(dev: DeviceBlocks, u: jax.Array) -> jax.Array:
    return dev.deg[dev.node2block[u]]


def adjacency_kernel(dev: DeviceBlocks, u: jax.Array,
                     v: jax.Array) -> jax.Array:
    if dev.nnz == 0:
        return jnp.zeros(u.shape, jnp.float64)
    a = dev.node2block[u].astype(jnp.int64)
    b = dev.node2block[v].astype(jnp.int64)
    qk = a * dev.s + b
    pos = jnp.clip(jnp.searchsorted(dev.key, qk), 0, dev.nnz - 1)
    sig = jnp.where(dev.key[pos] == qk, dev.sigma[pos], 0.0)
    return jnp.where(u == v, 0.0, sig)


def pagerank_row_sums(dev: DeviceBlocks, share: jax.Array) -> jax.Array:
    """Σ_e∈row deg_w[e]·share[col(e)] for every row — the power-step row
    reduction (padding contributes exact zeros)."""
    gathered = share[jnp.clip(dev.pad_cols, 0, max(dev.s - 1, 0))]
    return jnp.sum(dev.pad_degw * gathered, axis=-1)


def pagerank_update(dev: DeviceBlocks, p: jax.Array, new_rows: jax.Array,
                    damping: float) -> tuple[jax.Array, jax.Array]:
    """Damping + dangling redistribution + tolerance residual (replicated
    math: identical on every device from replicated ``p``/``new_rows``)."""
    vt = float(dev.num_nodes)
    dangling = jnp.sum(jnp.where(dev.deg <= 0, p * dev.sizes, 0.0))
    new = (1.0 - damping) / vt + damping * (new_rows + dangling / vt)
    return new, jnp.max(jnp.abs(new - p))


def triangle_rows(dev: DeviceBlocks, row_chunk: int) -> jax.Array:
    """Per-row triangle mass tri[a] = Σ_{b>a} σ_ab n_a n_b Σ_{c>b} σ_bc
    σ_ca n_c (float64[S]); total = tri.sum(). Chunked over rows so the
    [chunk, D, D] wedge tensor bounds memory; chunking never changes a
    row's value, so any chunk size yields identical per-row floats."""
    s, d = dev.s, dev.d
    if dev.nnz == 0:
        return jnp.zeros((s,), jnp.float64)
    chunk = max(1, min(row_chunk, s))
    n_chunks = -(-s // chunk)
    row_ids = jnp.arange(n_chunks * chunk, dtype=jnp.int32)
    row_ids = row_ids.reshape(n_chunks, chunk)

    def one_chunk(rows):
        live = rows < s
        a = jnp.clip(rows, 0, s - 1)
        b = dev.pad_cols[a]                                    # [R, D]
        sab = dev.pad_sigma[a]
        mask_b = (b > a[:, None]) & live[:, None]
        bc = jnp.clip(b, 0, s - 1)
        c = dev.pad_cols[bc]                                   # [R, D, D]
        sbc = dev.pad_sigma[bc]
        mask_c = (c >= 0) & (c > b[:, :, None]) & mask_b[:, :, None]
        qk = (jnp.clip(c, 0, s - 1).astype(jnp.int64) * s
              + a[:, None, None].astype(jnp.int64))
        pos = jnp.clip(jnp.searchsorted(dev.key, qk.ravel()),
                       0, dev.nnz - 1).reshape(qk.shape)
        sca = jnp.where(mask_c & (dev.key[pos] == qk), dev.sigma[pos], 0.0)
        nc = dev.sizes[jnp.clip(c, 0, s - 1)]
        inner = jnp.sum(jnp.where(mask_c, sbc * sca * nc, 0.0), axis=-1)
        w = jnp.where(
            mask_b,
            sab * inner * dev.sizes[a][:, None]
            * dev.sizes[jnp.clip(b, 0, s - 1)],
            0.0,
        )
        return jnp.sum(w, axis=-1)                             # [R]

    tri = jax.lax.map(one_chunk, row_ids).reshape(-1)
    return tri[:s]


def answer_kernel(dev: DeviceBlocks, kinds, u, v, pr_blocks, tri) -> jax.Array:
    """One fused batched dispatch: per-slot answer selected by kind."""
    deg = degree_kernel(dev, u)
    adj = adjacency_kernel(dev, u, v)
    prq = pr_blocks[dev.node2block[u]]
    tri_b = jnp.broadcast_to(tri, kinds.shape)
    return jnp.select(
        [kinds == KIND_DEGREE, kinds == KIND_ADJACENCY,
         kinds == KIND_PAGERANK, kinds == KIND_TRIANGLE],
        [deg, adj, prq, tri_b], 0.0)


def pack_set_counts(bs: BlockSummary, kinds, sets_a, sets_b):
    """Host-side packing of node-set queries to per-block count rows.

    ``sets_a``/``sets_b`` are length-B sequences (entries for non-set
    kinds are ignored; may be None). Returns float64 ``(cnt_a, cnt_b, ov)``
    of shape [B, S]: A-counts, B-counts and |A∩B|-counts per block — the
    same ``Q.block_counts`` dedup semantics as the numpy reference, so the
    jitted kernels see identical inputs.
    """
    kinds = np.asarray(kinds, np.int32)
    b, s = kinds.shape[0], bs.num_blocks
    cnt_a = np.zeros((b, s), np.float64)
    cnt_b = np.zeros((b, s), np.float64)
    ov = np.zeros((b, s), np.float64)

    def counts(nodes):
        out = np.zeros(s, np.float64)
        nodes = np.unique(np.asarray(nodes, dtype=np.int64))
        if nodes.size:
            np.add.at(out, bs.node2block[nodes], 1.0)
        return out, nodes

    for i, k in enumerate(kinds):
        if k not in _SET_KINDS:
            continue
        a = sets_a[i] if sets_a is not None and sets_a[i] is not None else ()
        cnt_a[i], a_u = counts(a)
        if k == KIND_CUT:
            bb = (sets_b[i]
                  if sets_b is not None and sets_b[i] is not None else ())
            cnt_b[i], b_u = counts(bb)
            ov[i], _ = counts(np.intersect1d(a_u, b_u, assume_unique=True))
    return cnt_a, cnt_b, ov


def cut_rows(dev: DeviceBlocks, c_a, c_b, ov) -> jax.Array:
    """Per-row cut contributions [B, S] from count rows [B, S].

    Row a contributes ``c_a[a]·Σ_j σ_aj·c_b[col_j] − σ_aa·ov[a]`` — summing
    over rows reproduces the numpy ``_cut_from_counts`` value. Slots are
    mapped with ``lax.map`` so memory stays O([S, D]) per slot, and each
    row reduces its padded entries in storage order on every tier."""
    s = dev.s
    ar = jnp.arange(s)
    sdiag = jnp.sum(dev.pad_sigma * (dev.pad_cols == ar[:, None]), axis=-1)

    def one(args):
        ca, cb, ov_s = args
        gathered = cb[jnp.clip(dev.pad_cols, 0, max(s - 1, 0))]
        rowsum = jnp.sum(dev.pad_sigma * gathered, axis=-1)
        return ca * rowsum - sdiag * ov_s

    return jax.lax.map(one, (c_a, c_b, ov))


def khop_step_rows(dev: DeviceBlocks, reach) -> jax.Array:
    """One BFS step on superedge support: row a becomes reachable when any
    neighbor with σ > 0 is in ``reach`` (bool [B, S] → bool [B, S])."""
    s = dev.s

    def one(r_s):
        g = r_s[jnp.clip(dev.pad_cols, 0, max(s - 1, 0))] & (
            dev.pad_sigma > 0)
        return jnp.any(g, axis=-1)

    return jax.lax.map(one, reach)


def analytics_answers(sizes, deg, a0, kinds, kvec, cnt_a, cnt_b, ov,
                      cut_rows_fn, khop_step_fn, khop_max: int):
    """(khop, cut, conductance) float64[B] from per-row callbacks.

    All post-row math (volumes, the BFS fixpoint loop, the member sums)
    operates on replicated [B, S]/[S] arrays in one canonical order, so as
    long as ``cut_rows_fn``/``khop_step_fn`` return the same per-row floats
    the three tiers agree bitwise. ``kvec`` carries k for khop slots;
    conductance derives its complement counts from ``cnt_a`` internally.
    """
    s = sizes.shape[0]
    is_cond = kinds == KIND_CONDUCTANCE
    cb_eff = jnp.where(is_cond[:, None], sizes[None, :] - cnt_a, cnt_b)
    ov_eff = jnp.where(is_cond[:, None], 0.0, ov)
    crows = cut_rows_fn(cnt_a, cb_eff, ov_eff)
    cut = jnp.sum(crows, axis=-1)
    vol_a = jnp.sum(cnt_a * deg[None, :], axis=-1)
    vol_c = jnp.sum((sizes[None, :] - cnt_a) * deg[None, :], axis=-1)
    denom = jnp.minimum(vol_a, vol_c)
    cond = jnp.where(denom > 0.0,
                     cut / jnp.where(denom > 0.0, denom, 1.0), 0.0)

    onehot = a0[:, None] == jnp.arange(s)[None, :]

    def body(t, r):
        inp = jnp.where(t == 0, onehot, r)
        nxt = khop_step_fn(inp) | r
        return jnp.where((t < kvec)[:, None], nxt, r)

    reach = jax.lax.fori_loop(0, khop_max, body, jnp.zeros_like(onehot))
    members = sizes[None, :] - onehot.astype(jnp.float64)
    khop = 1.0 + jnp.sum(jnp.where(reach, members, 0.0), axis=-1)
    return khop, cut, cond


def answer_kernel_full(dev: DeviceBlocks, kinds, u, v, pr_blocks, tri,
                       cnt_a, cnt_b, ov, khop_max: int,
                       cut_rows_fn=None, khop_step_fn=None) -> jax.Array:
    """The fused dispatch extended with the analytics kinds (khop carries
    k in the v lane; cut/conductance read the [B, S] count rows)."""
    base = answer_kernel(dev, kinds, u, v, pr_blocks, tri)
    if cut_rows_fn is None:
        cut_rows_fn = lambda a, b, o: cut_rows(dev, a, b, o)  # noqa: E731
    if khop_step_fn is None:
        khop_step_fn = lambda r: khop_step_rows(dev, r)       # noqa: E731
    a0 = dev.node2block[u]
    khop, cut, cond = analytics_answers(
        dev.sizes, dev.deg, a0, kinds, v, cnt_a, cnt_b, ov,
        cut_rows_fn, khop_step_fn, khop_max)
    return jnp.select(
        [kinds == KIND_KHOP, kinds == KIND_CUT,
         kinds == KIND_CONDUCTANCE],
        [khop, cut, cond], base)


def _pagerank_while(dev: DeviceBlocks, damping: float, iters: int,
                    tol: float, row_sums_fn) -> jax.Array:
    """The shared power-iteration loop; ``row_sums_fn`` is the only part
    that differs between the local and routed engines."""
    vt = float(dev.num_nodes)
    p0 = jnp.full((dev.s,), 1.0 / vt, jnp.float64)

    def cond(carry):
        _, i, done = carry
        return (i < iters) & ~done

    def body(carry):
        p, i, _ = carry
        share = jnp.where(dev.deg > 0, p / jnp.maximum(dev.deg, 1e-300),
                          0.0)
        new, resid = pagerank_update(dev, p, row_sums_fn(share), damping)
        return new, i + 1, resid < tol

    p, _, _ = jax.lax.while_loop(
        cond, body, (p0, jnp.int32(0), jnp.bool_(False)))
    return p


class QueryEngine:
    """Single-device batched query engine over one summary.

    Shapes are static per engine (one compilation per summary + batch
    size, amortized over the serving lifetime). PageRank and triangle
    density are computed lazily on first use and then served as a gather /
    a broadcast scalar.
    """

    def __init__(self, summary: SummaryResult | BlockSummary, *,
                 damping: float = 0.85, pagerank_iters: int = 50,
                 pagerank_tol: float = 1e-10, triangle_row_chunk: int = 64,
                 khop_max: int = 16):
        self.bs = (summary if isinstance(summary, BlockSummary)
                   else build_block_summary(summary))
        self.damping = damping
        self.pagerank_iters = pagerank_iters
        self.pagerank_tol = pagerank_tol
        self.triangle_row_chunk = triangle_row_chunk
        self.khop_max = khop_max
        self._pr_blocks = None
        self._tri = None
        with enable_x64():
            self.dev = device_blocks(self.bs)
            self._degree = jax.jit(degree_kernel)
            self._adjacency = jax.jit(adjacency_kernel)
            self._answer = jax.jit(answer_kernel)
            self._answer_full = jax.jit(
                lambda dev, kinds, u, v, pr, tri, ca, cb, ov:
                answer_kernel_full(dev, kinds, u, v, pr, tri, ca, cb, ov,
                                   khop_max))
            self._pagerank = jax.jit(
                lambda dev: _pagerank_while(
                    dev, damping, pagerank_iters, pagerank_tol,
                    lambda share: pagerank_row_sums(dev, share)))
            self._triangle = jax.jit(
                lambda dev: jnp.sum(triangle_rows(dev, triangle_row_chunk)))

    # ------------------------------------------------ lazy global queries
    def pagerank_blocks(self) -> jax.Array:
        if self._pr_blocks is None:
            with enable_x64():
                self._pr_blocks = self._pagerank(self.dev)
        return self._pr_blocks

    def triangle_density(self) -> float:
        if self._tri is None:
            with enable_x64():
                self._tri = self._triangle(self.dev)
        return float(self._tri)

    def pagerank_nodes(self, u) -> np.ndarray:
        pr = self.pagerank_blocks()
        with enable_x64():
            out = pr[self.dev.node2block[jnp.asarray(u, jnp.int32)]]
        return np.asarray(out)

    # --------------------------------------------------- batched queries
    def expected_degree(self, u) -> np.ndarray:
        with enable_x64():
            return np.asarray(
                self._degree(self.dev, jnp.asarray(u, jnp.int32)))

    def adjacency_weight(self, u, v) -> np.ndarray:
        with enable_x64():
            return np.asarray(self._adjacency(
                self.dev, jnp.asarray(u, jnp.int32),
                jnp.asarray(v, jnp.int32)))

    def answer_batch(self, kinds, u, v, cnt_a=None, cnt_b=None,
                     ov=None) -> np.ndarray:
        """Mixed-kind batch: ``kinds``/``u``/``v`` are int32[B]; returns
        float64[B]. The global-query inputs (PageRank vector, triangle
        scalar) are materialized only if the batch asks for them. Batches
        containing analytics kinds (khop/cut/conductance) go through the
        extended kernel; ``cnt_a``/``cnt_b``/``ov`` are the [B, S] count
        rows from :func:`pack_set_counts` (zeros when absent)."""
        kinds = np.asarray(kinds, np.int32)
        pr = (self.pagerank_blocks() if (kinds == KIND_PAGERANK).any()
              else None)
        tri = (self.triangle_density() if (kinds == KIND_TRIANGLE).any()
               else 0.0)
        needs = bool(np.isin(kinds, _ANALYTIC_KINDS).any())
        with enable_x64():
            if pr is None:
                pr = jnp.zeros((self.dev.s,), jnp.float64)
            args = (self.dev, jnp.asarray(kinds),
                    jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                    pr, jnp.asarray(tri, jnp.float64))
            if not needs:
                return np.asarray(self._answer(*args))
            shape = (kinds.shape[0], self.dev.s)
            ca, cb, oo = (
                jnp.zeros(shape, jnp.float64) if x is None
                else jnp.asarray(x, jnp.float64)
                for x in (cnt_a, cnt_b, ov))
            return np.asarray(self._answer_full(*args, ca, cb, oo))

    # ------------------------------------------------- analytics queries
    def cut_weight(self, sets_a, sets_b) -> np.ndarray:
        """Batched Ĝ cut weight between node-set pairs (length-B lists)."""
        b = len(sets_a)
        kinds = np.full(b, KIND_CUT, np.int32)
        ca, cb, ov = pack_set_counts(self.bs, kinds, sets_a, sets_b)
        z = np.zeros(b, np.int32)
        return self.answer_batch(kinds, z, z, ca, cb, ov)

    def conductance(self, sets_a) -> np.ndarray:
        """Batched Ĝ conductance of node sets (length-B list)."""
        b = len(sets_a)
        kinds = np.full(b, KIND_CONDUCTANCE, np.int32)
        ca, cb, ov = pack_set_counts(self.bs, kinds, sets_a, None)
        z = np.zeros(b, np.int32)
        return self.answer_batch(kinds, z, z, ca, cb, ov)

    def k_hop_size(self, u, k) -> np.ndarray:
        """Batched expected k-hop neighborhood size (u, k broadcast)."""
        u = np.asarray(u, np.int32).ravel()
        k = np.broadcast_to(np.asarray(k, np.int32), u.shape)
        kinds = np.full(u.shape, KIND_KHOP, np.int32)
        return self.answer_batch(kinds, u, k)


class RoutedQueryEngine:
    """Owner-routed multi-device engine: same kernels, psum'd merge.

    Each supernode (block) is owned by ``MeshRules.owner(id, salt)`` — the
    re-drawable hash the distributed merge step already routes pairs with,
    so tooling that predicts record placement agrees across subsystems.
    Per-node queries are answered only by the owner of the target's block;
    global queries (PageRank rows, triangle rows) are computed per owned
    row and merged with a psum of disjoint contributions — exact, and
    bit-identical to :class:`QueryEngine` because every row reduces the
    same padded layout in the same order (tests/query_serve_check.py).

    A mesh change (elastic shrink/grow) is a routing-table rebuild:
    construct a new engine on the survivor mesh — the owner hash only
    depends on device *count* and salt.
    """

    def __init__(self, summary: SummaryResult | BlockSummary, mesh, *,
                 salt: int = 0, damping: float = 0.85,
                 pagerank_iters: int = 50, pagerank_tol: float = 1e-10,
                 triangle_row_chunk: int = 64, khop_max: int = 16):
        self.bs = (summary if isinstance(summary, BlockSummary)
                   else build_block_summary(summary))
        self.mesh = mesh
        self.rules = make_rules(mesh, "summarize")
        self.salt = salt
        self.khop_max = khop_max
        self.axis_names = tuple(mesh.axis_names)
        self._pr_blocks = None
        self._tri = None
        axis_names = self.axis_names
        rep = self.rules.replicated

        with enable_x64():
            self.dev = device_blocks(self.bs)
            # routing table: block index -> owning device (host-built once;
            # rebuilt by constructing a new engine after a re-mesh)
            self.block_owner = jnp.asarray(np.asarray(self.rules.owner(
                jnp.asarray(self.bs.ids, jnp.int32),
                jnp.uint32(salt))), jnp.int32)

            def my_device():
                return jax.lax.axis_index(axis_names).astype(jnp.int32)

            def routed_rows(x_rows, owner):
                """Keep rows this device owns, psum the one-hot merge."""
                mine = owner == my_device()
                return jax.lax.psum(jnp.where(mine, x_rows, 0.0),
                                    axis_names)

            def pr_body(dev, owner):
                return _pagerank_while(
                    dev, damping, pagerank_iters, pagerank_tol,
                    lambda share: routed_rows(
                        pagerank_row_sums(dev, share), owner))

            self._pagerank = jax.jit(shard_map(
                pr_body, mesh=mesh, in_specs=(rep, rep), out_specs=rep,
                check_vma=False))

            def tri_body(dev, owner):
                tri = routed_rows(triangle_rows(dev, triangle_row_chunk),
                                  owner)
                return jnp.sum(tri)

            self._triangle = jax.jit(shard_map(
                tri_body, mesh=mesh, in_specs=(rep, rep), out_specs=rep,
                check_vma=False))

            def route_mask(dev, owner, kinds, u):
                """Which slots this device answers (disjoint across devs)."""
                is_global = jnp.zeros(kinds.shape, bool)
                for k in _GLOBAL_KINDS:
                    is_global |= kinds == k
                target = owner[dev.node2block[u]]
                return jnp.where(is_global, my_device() == 0,
                                 target == my_device())

            def answer_body(dev, owner, kinds, u, v, pr_blocks, tri):
                ans = answer_kernel(dev, kinds, u, v, pr_blocks, tri)
                mine = route_mask(dev, owner, kinds, u)
                return jax.lax.psum(jnp.where(mine, ans, 0.0), axis_names)

            self._answer = jax.jit(shard_map(
                answer_body, mesh=mesh, in_specs=(rep,) * 7,
                out_specs=rep, check_vma=False))

            def answer_full_body(dev, owner, kinds, u, v, pr_blocks, tri,
                                 ca, cb, ov):
                mine_rows = owner[None, :] == my_device()

                def cut_fn(a_, b_, o_):
                    rows = cut_rows(dev, a_, b_, o_)
                    return jax.lax.psum(jnp.where(mine_rows, rows, 0.0),
                                        axis_names)

                def step_fn(r):
                    stepped = jnp.where(mine_rows,
                                        khop_step_rows(dev, r), False)
                    return jax.lax.psum(stepped.astype(jnp.int32),
                                        axis_names) > 0

                ans = answer_kernel_full(dev, kinds, u, v, pr_blocks, tri,
                                         ca, cb, ov, khop_max,
                                         cut_fn, step_fn)
                mine = route_mask(dev, owner, kinds, u)
                return jax.lax.psum(jnp.where(mine, ans, 0.0), axis_names)

            self._answer_full = jax.jit(shard_map(
                answer_full_body, mesh=mesh, in_specs=(rep,) * 10,
                out_specs=rep, check_vma=False))

    def owner_counts(self) -> np.ndarray:
        """Blocks per owning device — the routing-table histogram."""
        return np.bincount(np.asarray(self.block_owner),
                           minlength=self.rules.n_devices)

    def pagerank_blocks(self) -> jax.Array:
        if self._pr_blocks is None:
            with enable_x64(), self.mesh:
                self._pr_blocks = self._pagerank(self.dev,
                                                 self.block_owner)
        return self._pr_blocks

    def pagerank_nodes(self, u) -> np.ndarray:
        pr = self.pagerank_blocks()
        with enable_x64():
            out = pr[self.dev.node2block[jnp.asarray(u, jnp.int32)]]
        return np.asarray(out)

    def triangle_density(self) -> float:
        if self._tri is None:
            with enable_x64(), self.mesh:
                self._tri = self._triangle(self.dev, self.block_owner)
        return float(self._tri)

    def answer_batch(self, kinds, u, v, cnt_a=None, cnt_b=None,
                     ov=None) -> np.ndarray:
        kinds = np.asarray(kinds, np.int32)
        pr = (self.pagerank_blocks() if (kinds == KIND_PAGERANK).any()
              else None)
        tri = (self.triangle_density() if (kinds == KIND_TRIANGLE).any()
               else 0.0)
        needs = bool(np.isin(kinds, _ANALYTIC_KINDS).any())
        with enable_x64(), self.mesh:
            if pr is None:
                pr = jnp.zeros((self.dev.s,), jnp.float64)
            args = (self.dev, self.block_owner, jnp.asarray(kinds),
                    jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                    pr, jnp.asarray(tri, jnp.float64))
            if not needs:
                return np.asarray(self._answer(*args))
            shape = (kinds.shape[0], self.dev.s)
            ca, cb, oo = (
                jnp.zeros(shape, jnp.float64) if x is None
                else jnp.asarray(x, jnp.float64)
                for x in (cnt_a, cnt_b, ov))
            return np.asarray(self._answer_full(*args, ca, cb, oo))

    cut_weight = QueryEngine.cut_weight
    conductance = QueryEngine.conductance
    k_hop_size = QueryEngine.k_hop_size


# ------------------------------------------------------ partitioned tier
# DESIGN.md §16: each device keeps only its owned rows of the padded
# [S, D] block CSR plus precomputed halo tables; cross-block lookups are
# resolved by all-gathering the owned-value *slab* (size ~S/P per device)
# and indexing it with (src_device, src_position) halo coordinates — the
# full summary is never materialized on any device.

@dataclasses.dataclass(frozen=True)
class PartitionTables:
    """Host-built partition + halo index tables for one (summary, P).

    Deterministic function of ``(BlockSummary, owner, n_devices,
    dense_row_nnz)`` — rebuilt from scratch on an elastic re-mesh; the
    halo-table property test pins determinism and coverage. All per-device
    lists are padded to the per-table max with -1.

    * ``own_gids[p]``      — global block ids device p owns (sorted);
    * ``halo_*[p]``        — every remote block referenced by p's rows,
      with its (owner device, position-in-owner's-list) coordinates: the
      PageRank share exchange gathers owned slabs and reads these;
    * ``row_halo_gids[p]`` — the non-dense subset whose full padded rows
      are resident on p (triangle wedge closure needs whole rows);
    * ``dense_gids``       — rows with nnz > dense_row_nnz ("adversarially
      dense"): excluded from every resident halo and fetched at kernel
      time via a second-hop all-gather of the owner-held dense slab;
    * ``loc_share/loc_row[p, i, j]`` — per owned-row entry, the extended
      index of that entry's column in [own | halo | (dense) | sentinel].
    """

    n_devices: int
    s: int
    d: int
    dense_row_nnz: int | None
    owner: np.ndarray          # int32[S] block -> device
    block_pos: np.ndarray      # int32[S] position in owner's own list
    own_gids: np.ndarray       # int32[P, S_own]
    halo_gids: np.ndarray      # int32[P, H]
    halo_src_dev: np.ndarray   # int32[P, H]
    halo_src_pos: np.ndarray   # int32[P, H]
    row_halo_gids: np.ndarray  # int32[P, Ht]
    dense_gids: np.ndarray     # int32[n_dense] (sorted)
    dense_slots: np.ndarray    # int32[P, Dm] dense rows per owner
    loc_share: np.ndarray      # int32[P, S_own, D]
    loc_row: np.ndarray        # int32[P, S_own, D]


def build_partition_tables(bs: BlockSummary, owner, n_devices: int,
                           dense_row_nnz: int | None = None,
                           ) -> PartitionTables:
    """Build the per-device row partition and halo index tables (host)."""
    owner = np.asarray(owner, np.int32)
    p = int(n_devices)
    s = bs.num_blocks
    d = max(1, bs.max_row_nnz())
    pad_cols, _, _ = host_padded_rows(bs)

    row_nnz = np.diff(bs.indptr)
    dense = np.zeros(s, bool)
    if dense_row_nnz is not None and s:
        dense = row_nnz > int(dense_row_nnz)
    dense_gids = np.flatnonzero(dense).astype(np.int32)

    own_lists = [np.flatnonzero(owner == q).astype(np.int32)
                 for q in range(p)]
    s_own = max([1] + [l.size for l in own_lists])
    block_pos = np.zeros(s, np.int32)
    for l in own_lists:
        block_pos[l] = np.arange(l.size, dtype=np.int32)

    dense_lists = [l[dense[l]] for l in own_lists]
    dmax = max([1] + [l.size for l in dense_lists])
    dense_slots = np.full((p, dmax), -1, np.int32)
    dense_slab_pos = np.full(s, -1, np.int32)  # gid -> slot in [P·Dm] slab
    for q, l in enumerate(dense_lists):
        dense_slots[q, :l.size] = l
        dense_slab_pos[l] = q * dmax + np.arange(l.size, dtype=np.int32)

    halo_lists, row_halo_lists = [], []
    for q in range(p):
        refs = pad_cols[own_lists[q]]
        refs = np.unique(refs[refs >= 0]).astype(np.int32)
        remote = refs[owner[refs] != q]
        halo_lists.append(remote)
        row_halo_lists.append(remote[~dense[remote]])
    h = max([1] + [l.size for l in halo_lists])
    ht = max([1] + [l.size for l in row_halo_lists])

    own_gids = np.full((p, s_own), -1, np.int32)
    halo_gids = np.full((p, h), -1, np.int32)
    halo_src_dev = np.zeros((p, h), np.int32)
    halo_src_pos = np.zeros((p, h), np.int32)
    row_halo_gids = np.full((p, ht), -1, np.int32)
    share_sent = s_own + h
    row_sent = s_own + ht + p * dmax
    loc_share = np.full((p, s_own, d), share_sent, np.int32)
    loc_row = np.full((p, s_own, d), row_sent, np.int32)
    for q in range(p):
        own, hl, rhl = own_lists[q], halo_lists[q], row_halo_lists[q]
        own_gids[q, :own.size] = own
        halo_gids[q, :hl.size] = hl
        halo_src_dev[q, :hl.size] = owner[hl]
        halo_src_pos[q, :hl.size] = block_pos[hl]
        row_halo_gids[q, :rhl.size] = rhl
        # gid -> extended-index maps for this device (padding key s -> pad)
        share_map = np.full(s + 1, share_sent, np.int64)
        share_map[hl] = s_own + np.arange(hl.size)
        share_map[own] = block_pos[own]
        row_map = np.full(s + 1, row_sent, np.int64)
        dm = np.flatnonzero(dense_slab_pos >= 0)
        row_map[dm] = s_own + ht + dense_slab_pos[dm]
        row_map[rhl] = s_own + np.arange(rhl.size)
        row_map[own] = block_pos[own]  # own rows win over the dense slab
        cols_own = pad_cols[own]
        safe = np.where(cols_own >= 0, cols_own, s)
        loc_share[q, :own.size] = share_map[safe]
        loc_row[q, :own.size] = row_map[safe]

    return PartitionTables(
        n_devices=p, s=s, d=d, dense_row_nnz=dense_row_nnz, owner=owner,
        block_pos=block_pos, own_gids=own_gids, halo_gids=halo_gids,
        halo_src_dev=halo_src_dev, halo_src_pos=halo_src_pos,
        row_halo_gids=row_halo_gids, dense_gids=dense_gids,
        dense_slots=dense_slots, loc_share=loc_share, loc_row=loc_row)


@dataclasses.dataclass(frozen=True)
class PartBlocks:
    """Device-sharded [P, ...] leaves of the partitioned tier (axis 0 is
    the device axis; each device addresses only its own [1, ...] slice
    inside shard_map)."""

    own_gids: jax.Array     # int32[P, S_own]
    own_cols: jax.Array     # int32[P, S_own, D]
    own_sigma: jax.Array    # float64[P, S_own, D]
    own_degw: jax.Array     # float64[P, S_own, D]
    loc_share: jax.Array    # int32[P, S_own, D]
    loc_row: jax.Array      # int32[P, S_own, D]
    halo_src_dev: jax.Array  # int32[P, H]
    halo_src_pos: jax.Array  # int32[P, H]
    rh_cols: jax.Array      # int32[P, Ht, D] resident halo rows
    rh_sigma: jax.Array     # float64[P, Ht, D]
    dn_cols: jax.Array      # int32[P, Dm, D] dense (second-hop) rows
    dn_sigma: jax.Array     # float64[P, Dm, D]


jax.tree_util.register_pytree_node(
    PartBlocks,
    lambda b: (tuple(getattr(b, f.name)
                     for f in dataclasses.fields(PartBlocks)), None),
    lambda _, leaves: PartBlocks(*leaves),
)


@dataclasses.dataclass(frozen=True)
class RepBlocks:
    """Replicated O(S)/O(V) metadata of the partitioned tier (the paper's
    supernode count S is millions at most while rows cost S·D — only the
    row payload is worth partitioning)."""

    node2block: jax.Array  # int32[V]
    sizes: jax.Array       # float64[S]
    deg: jax.Array         # float64[S]
    owner: jax.Array       # int32[S]
    block_pos: jax.Array   # int32[S]
    gids_all: jax.Array    # int32[P, S_own] (replicated copy of own_gids)


jax.tree_util.register_pytree_node(
    RepBlocks,
    lambda b: (tuple(getattr(b, f.name)
                     for f in dataclasses.fields(RepBlocks)), None),
    lambda _, leaves: RepBlocks(*leaves),
)


def _squeeze_part(pb: PartBlocks) -> PartBlocks:
    """Drop the leading per-device axis inside shard_map bodies."""
    return jax.tree_util.tree_map(lambda x: x[0], pb)


class PartitionedQueryEngine:
    """Memory-partitioned routed engine: device-sharded block CSR rows.

    Same wire format and bit-identical answers as the replicated tiers,
    but each device's resident summary is its owned rows (~S/P) plus the
    halo — the padded rows its owned rows reference on other devices —
    rather than the full [S, D] CSR. Cross-device σ/share lookups go
    through the precomputed halo tables: PageRank all-gathers the owned
    [P, S_own] value slab per step and reads remote shares at
    (src_device, src_position); the triangle wedge closure keeps full
    resident copies of (non-dense) halo rows. Rows denser than
    ``dense_row_nnz`` are excluded from every resident halo and fetched by
    a second-hop all-gather of the owner-held dense slab at kernel time,
    bounding resident memory against adversarially dense rows.

    Bit-identity holds for the same reason as the routed tier: every
    per-row reduction runs over the same padded entries in the same
    storage order, per-row results are merged into canonical [S]-indexed
    vectors by a psum of disjoint scatters, and all post-row math is
    replicated. An elastic re-mesh is a table rebuild: construct a new
    engine on the survivor mesh.
    """

    def __init__(self, summary: SummaryResult | BlockSummary, mesh, *,
                 salt: int = 0, damping: float = 0.85,
                 pagerank_iters: int = 50, pagerank_tol: float = 1e-10,
                 triangle_row_chunk: int = 64, khop_max: int = 16,
                 dense_row_nnz: int | None = None):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from repro.dist import owner_hash_np

        self.bs = (summary if isinstance(summary, BlockSummary)
                   else build_block_summary(summary))
        self.mesh = mesh
        self.rules = make_rules(mesh, "summarize")
        self.salt = salt
        self.khop_max = khop_max
        self.dense_row_nnz = dense_row_nnz
        self.axis_names = tuple(mesh.axis_names)
        self._pr_blocks = None
        self._tri = None
        axis_names = self.axis_names
        bs = self.bs
        n_dev = self.rules.n_devices
        owner = owner_hash_np(bs.ids, salt, n_dev)
        self.tables = t = build_partition_tables(
            bs, owner, n_dev, dense_row_nnz)
        pad_cols, pad_sigma, pad_degw = host_padded_rows(bs)
        s, d = t.s, t.d
        num_nodes = bs.num_nodes

        def rows_of(gids, arr, fill):
            """Stack per-device padded rows: [P, N] gids -> [P, N, ...]."""
            out = arr[np.where(gids >= 0, gids, 0)].copy()
            out[gids < 0] = fill
            return out

        with enable_x64():
            shard = NamedSharding(mesh, P(axis_names))
            rep_sh = NamedSharding(mesh, P())

            def put(x, sh):
                return jax.device_put(jnp.asarray(x), sh)

            self.part = PartBlocks(
                own_gids=put(t.own_gids, shard),
                own_cols=put(rows_of(t.own_gids, pad_cols, -1), shard),
                own_sigma=put(rows_of(t.own_gids, pad_sigma, 0.0), shard),
                own_degw=put(rows_of(t.own_gids, pad_degw, 0.0), shard),
                loc_share=put(t.loc_share, shard),
                loc_row=put(t.loc_row, shard),
                halo_src_dev=put(t.halo_src_dev, shard),
                halo_src_pos=put(t.halo_src_pos, shard),
                rh_cols=put(rows_of(t.row_halo_gids, pad_cols, -1), shard),
                rh_sigma=put(rows_of(t.row_halo_gids, pad_sigma, 0.0),
                             shard),
                dn_cols=put(rows_of(t.dense_slots, pad_cols, -1), shard),
                dn_sigma=put(rows_of(t.dense_slots, pad_sigma, 0.0),
                             shard),
            )
            self.rep = RepBlocks(
                node2block=put(bs.node2block.astype(np.int32), rep_sh),
                sizes=put(bs.sizes.astype(np.float64), rep_sh),
                deg=put(bs.deg.astype(np.float64), rep_sh),
                owner=put(t.owner, rep_sh),
                block_pos=put(t.block_pos, rep_sh),
                gids_all=put(t.own_gids, rep_sh),
            )
            part_spec = P(axis_names)
            rep_spec = P()

            def my_device():
                return jax.lax.axis_index(axis_names).astype(jnp.int32)

            def scatter1(vals, gids):
                """[S_own] owned values -> [S] canonical (pre-psum)."""
                safe = jnp.where(gids >= 0, gids, s)
                return jnp.zeros(s + 1, vals.dtype).at[safe].set(vals)[:s]

            def scatter2(vals, gids):
                """[B, S_own] -> [B, S] canonical (pre-psum)."""
                safe = jnp.where(gids >= 0, gids, s)
                out = jnp.zeros(vals.shape[:-1] + (s + 1,), vals.dtype)
                return out.at[:, safe].set(vals)[:, :s]

            def full_from_slab(slab, gids_all):
                """All-gathered owned slab [P, S_own] -> canonical [S]."""
                safe = jnp.where(gids_all >= 0, gids_all, s)
                return (jnp.zeros(s + 1, slab.dtype)
                        .at[safe.ravel()].set(slab.ravel())[:s])

            # ------------------------------------------------- pagerank
            def pr_body(pb, rb):
                pb = _squeeze_part(pb)
                s_own = pb.own_gids.shape[0]
                valid = pb.own_gids >= 0
                gsafe = jnp.where(valid, pb.own_gids, 0)
                deg_own = jnp.where(valid, rb.deg[gsafe], 0.0)
                vt = float(num_nodes)
                p0 = jnp.where(valid, 1.0 / vt, 0.0)

                def cond(carry):
                    _, i, done = carry
                    return (i < pagerank_iters) & ~done

                def body(carry):
                    p_own, i, _ = carry
                    share_own = jnp.where(
                        deg_own > 0,
                        p_own / jnp.maximum(deg_own, 1e-300), 0.0)
                    slab = jax.lax.all_gather(
                        jnp.stack([p_own, share_own]), axis_names)
                    halo_share = slab[pb.halo_src_dev, 1, pb.halo_src_pos]
                    share_ext = jnp.concatenate(
                        [share_own, halo_share,
                         jnp.zeros((1,), jnp.float64)])
                    row_sums = jnp.sum(
                        pb.own_degw * share_ext[pb.loc_share], axis=-1)
                    p_full = full_from_slab(slab[:, 0, :], rb.gids_all)
                    dangling = jnp.sum(
                        jnp.where(rb.deg <= 0, p_full * rb.sizes, 0.0))
                    new = ((1.0 - damping) / vt
                           + damping * (row_sums + dangling / vt))
                    new = jnp.where(valid, new, 0.0)
                    resid = jax.lax.pmax(jnp.max(jnp.abs(new - p_own)),
                                         axis_names)
                    return new, i + 1, resid < pagerank_tol

                p_own, _, _ = jax.lax.while_loop(
                    cond, body,
                    (p0, jnp.int32(0), jnp.bool_(False)))
                slab = jax.lax.all_gather(p_own, axis_names)
                return full_from_slab(slab, rb.gids_all)

            self._pagerank = jax.jit(shard_map(
                pr_body, mesh=mesh, in_specs=(part_spec, rep_spec),
                out_specs=rep_spec, check_vma=False))

            # ------------------------------------------------- triangle
            def ext_row_tables(pb):
                """[own | resident halo | gathered dense slab | sentinel]
                row tables for the wedge closure."""
                dmx = pb.dn_cols.shape[0]
                dn_cols = jax.lax.all_gather(
                    pb.dn_cols, axis_names).reshape(n_dev * dmx, d)
                dn_sigma = jax.lax.all_gather(
                    pb.dn_sigma, axis_names).reshape(n_dev * dmx, d)
                ext_cols = jnp.concatenate(
                    [pb.own_cols, pb.rh_cols, dn_cols,
                     jnp.full((1, d), -1, jnp.int32)])
                ext_sigma = jnp.concatenate(
                    [pb.own_sigma, pb.rh_sigma, dn_sigma,
                     jnp.zeros((1, d), jnp.float64)])
                return ext_cols, ext_sigma

            def tri_body(pb, rb):
                pb = _squeeze_part(pb)
                s_own = pb.own_gids.shape[0]
                ext_cols, ext_sigma = ext_row_tables(pb)
                chunk = max(1, min(triangle_row_chunk, s_own))
                n_chunks = -(-s_own // chunk)
                row_ids = jnp.arange(n_chunks * chunk, dtype=jnp.int32)
                row_ids = row_ids.reshape(n_chunks, chunk)

                def one_chunk(idx):
                    i = jnp.clip(idx, 0, s_own - 1)
                    ga = pb.own_gids[i]
                    live = (idx < s_own) & (ga >= 0)
                    a = jnp.clip(ga, 0, s - 1)
                    b = pb.own_cols[i]                       # [R, D]
                    sab = pb.own_sigma[i]
                    mask_b = (b > a[:, None]) & live[:, None]
                    e = pb.loc_row[i]
                    c = ext_cols[e]                          # [R, D, D]
                    sbc = ext_sigma[e]
                    mask_c = (c >= 0) & (c > b[:, :, None]) & (
                        mask_b[:, :, None])
                    # third side σ_ca looked up in row a's local columns —
                    # same float as the replicated global-key search
                    # because the CSR is symmetric (σ_ca == σ_ac).
                    srow = jnp.where(b < 0, s, b)            # ascending
                    q = jnp.clip(c, 0, s - 1).reshape(c.shape[0], -1)
                    pos = jax.vmap(jnp.searchsorted)(srow, q)
                    pos = jnp.clip(pos, 0, d - 1)
                    hit = jnp.take_along_axis(srow, pos, 1) == q
                    sca = jnp.where(
                        hit, jnp.take_along_axis(sab, pos, 1),
                        0.0).reshape(c.shape)
                    nc = rb.sizes[jnp.clip(c, 0, s - 1)]
                    inner = jnp.sum(
                        jnp.where(mask_c, sbc * sca * nc, 0.0), axis=-1)
                    w = jnp.where(
                        mask_b,
                        sab * inner * rb.sizes[a][:, None]
                        * rb.sizes[jnp.clip(b, 0, s - 1)],
                        0.0)
                    return jnp.sum(w, axis=-1)

                tri_own = jax.lax.map(one_chunk, row_ids).reshape(-1)
                tri_own = tri_own[:s_own]
                tri_full = jax.lax.psum(
                    scatter1(tri_own, pb.own_gids), axis_names)
                return jnp.sum(tri_full)

            self._triangle = jax.jit(shard_map(
                tri_body, mesh=mesh, in_specs=(part_spec, rep_spec),
                out_specs=rep_spec, check_vma=False))

            # --------------------------------------------------- answers
            def base_answers(pb, rb, kinds, u, v, pr_full, tri):
                """Point/global answers from owned rows only (valid on the
                routing owner; garbage elsewhere is masked by routing)."""
                s_own = pb.own_gids.shape[0]
                a0 = rb.node2block[u]
                bblk = rb.node2block[v]
                i = jnp.clip(rb.block_pos[a0], 0, s_own - 1)
                row = pb.own_cols[i]                         # [B, D]
                srow = jnp.where(row < 0, s, row)
                pos = jax.vmap(jnp.searchsorted)(srow, bblk[:, None])
                pos = jnp.clip(pos[:, 0], 0, d - 1)
                hit = jnp.take_along_axis(
                    srow, pos[:, None], 1)[:, 0] == bblk
                sig = jnp.where(
                    hit,
                    jnp.take_along_axis(
                        pb.own_sigma[i], pos[:, None], 1)[:, 0], 0.0)
                adj = jnp.where(u == v, 0.0, sig)
                return jnp.select(
                    [kinds == KIND_DEGREE, kinds == KIND_ADJACENCY,
                     kinds == KIND_PAGERANK, kinds == KIND_TRIANGLE],
                    [rb.deg[a0], adj, pr_full[a0],
                     jnp.broadcast_to(tri, kinds.shape)], 0.0)

            def route_mask(rb, kinds, u):
                is_global = jnp.zeros(kinds.shape, bool)
                for k in _GLOBAL_KINDS:
                    is_global |= kinds == k
                target = rb.owner[rb.node2block[u]]
                return jnp.where(is_global, my_device() == 0,
                                 target == my_device())

            def answer_body(pb, rb, kinds, u, v, pr_full, tri):
                pb = _squeeze_part(pb)
                ans = base_answers(pb, rb, kinds, u, v, pr_full, tri)
                mine = route_mask(rb, kinds, u)
                return jax.lax.psum(jnp.where(mine, ans, 0.0), axis_names)

            self._answer = jax.jit(shard_map(
                answer_body, mesh=mesh,
                in_specs=(part_spec,) + (rep_spec,) * 6,
                out_specs=rep_spec, check_vma=False))

            def answer_full_body(pb, rb, kinds, u, v, pr_full, tri,
                                 ca, cb, ov):
                pb = _squeeze_part(pb)
                base = base_answers(pb, rb, kinds, u, v, pr_full, tri)
                gsafe = jnp.clip(pb.own_gids, 0, s - 1)
                valid = pb.own_gids >= 0
                sdiag = jnp.sum(
                    pb.own_sigma * (pb.own_cols == gsafe[:, None]),
                    axis=-1)

                def cut_fn(a_, b_, o_):
                    def one(args):
                        c_a, c_b, oo = args
                        gath = c_b[jnp.clip(pb.own_cols, 0,
                                            max(s - 1, 0))]
                        rowsum = jnp.sum(pb.own_sigma * gath, axis=-1)
                        return jnp.where(
                            valid,
                            c_a[gsafe] * rowsum - sdiag * oo[gsafe], 0.0)

                    rows_own = jax.lax.map(one, (a_, b_, o_))
                    return jax.lax.psum(
                        scatter2(rows_own, pb.own_gids), axis_names)

                def step_fn(r):
                    def one(r_s):
                        g = r_s[jnp.clip(pb.own_cols, 0,
                                         max(s - 1, 0))] & (
                            pb.own_sigma > 0)
                        return jnp.any(g, axis=-1)

                    rows_own = jax.lax.map(one, r)
                    full = jax.lax.psum(
                        scatter2(rows_own.astype(jnp.int32),
                                 pb.own_gids), axis_names)
                    return full > 0

                a0 = rb.node2block[u]
                khop, cut, cond = analytics_answers(
                    rb.sizes, rb.deg, a0, kinds, v, ca, cb, ov,
                    cut_fn, step_fn, khop_max)
                ans = jnp.select(
                    [kinds == KIND_KHOP, kinds == KIND_CUT,
                     kinds == KIND_CONDUCTANCE],
                    [khop, cut, cond], base)
                mine = route_mask(rb, kinds, u)
                return jax.lax.psum(jnp.where(mine, ans, 0.0), axis_names)

            self._answer_full = jax.jit(shard_map(
                answer_full_body, mesh=mesh,
                in_specs=(part_spec,) + (rep_spec,) * 9,
                out_specs=rep_spec, check_vma=False))

    # ------------------------------------------------------------ queries
    def owner_counts(self) -> np.ndarray:
        return np.bincount(self.tables.owner,
                           minlength=self.rules.n_devices)

    def pagerank_blocks(self) -> jax.Array:
        if self._pr_blocks is None:
            with enable_x64(), self.mesh:
                self._pr_blocks = self._pagerank(self.part, self.rep)
        return self._pr_blocks

    def pagerank_nodes(self, u) -> np.ndarray:
        pr = self.pagerank_blocks()
        with enable_x64():
            out = pr[self.rep.node2block[jnp.asarray(u, jnp.int32)]]
        return np.asarray(out)

    def triangle_density(self) -> float:
        if self._tri is None:
            with enable_x64(), self.mesh:
                self._tri = self._triangle(self.part, self.rep)
        return float(self._tri)

    def answer_batch(self, kinds, u, v, cnt_a=None, cnt_b=None,
                     ov=None) -> np.ndarray:
        kinds = np.asarray(kinds, np.int32)
        pr = (self.pagerank_blocks() if (kinds == KIND_PAGERANK).any()
              else None)
        tri = (self.triangle_density() if (kinds == KIND_TRIANGLE).any()
               else 0.0)
        needs = bool(np.isin(kinds, _ANALYTIC_KINDS).any())
        s = self.tables.s
        with enable_x64(), self.mesh:
            if pr is None:
                pr = jnp.zeros((s,), jnp.float64)
            args = (self.part, self.rep, jnp.asarray(kinds),
                    jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                    pr, jnp.asarray(tri, jnp.float64))
            if not needs:
                return np.asarray(self._answer(*args))
            shape = (kinds.shape[0], s)
            ca, cb, oo = (
                jnp.zeros(shape, jnp.float64) if x is None
                else jnp.asarray(x, jnp.float64)
                for x in (cnt_a, cnt_b, ov))
            return np.asarray(self._answer_full(*args, ca, cb, oo))

    cut_weight = QueryEngine.cut_weight
    conductance = QueryEngine.conductance
    k_hop_size = QueryEngine.k_hop_size

    # ------------------------------------------------- memory accounting
    def partition_stats(self) -> dict:
        t = self.tables
        return {
            "devices": int(t.n_devices),
            "s": int(t.s),
            "d": int(t.d),
            "s_own_max": int(t.own_gids.shape[1]),
            "halo_max": int(t.halo_gids.shape[1]),
            "row_halo_max": int(t.row_halo_gids.shape[1]),
            "dense_rows": int(t.dense_gids.size),
            "owner_counts": self.owner_counts().tolist(),
            "halo_counts": (t.halo_gids >= 0).sum(axis=1).tolist(),
            "resident_bytes_per_device": self.resident_bytes_per_device(),
            "replicated_row_bytes": self.replicated_row_bytes(),
        }

    def resident_bytes_per_device(self) -> int:
        """Measured per-device bytes of the sharded row payload (every
        [P, ...] leaf shards evenly: one [1, ...] slice per device)."""
        return int(sum(
            leaf.addressable_shards[0].data.nbytes
            for leaf in jax.tree_util.tree_leaves(self.part)))

    def replicated_row_bytes(self) -> int:
        """What the replicated tiers keep per device for the same rows:
        the full padded [S, D] CSR (cols i32 + σ f64 + deg_w f64)."""
        t = self.tables
        return int(t.s) * int(t.d) * (4 + 8 + 8)
