"""Further sparsification (Sect. 3.2.4): drop superedges until Size(Ḡ) ≤ k.

Vectorized version of the paper's three steps:
  1. closed-form RE_p increase per kept superedge (footnote 4):
         ΔRE₁ = (2|E_AB|/|Π_AB| - 1)·|E_AB|      ΔRE₂² = |E_AB|²/|Π_AB|
  2. the ξ-th smallest increase Δ_ξ via an order statistic
     (``jnp.sort`` — the paper uses median-of-medians selection; on TPU a
     bitonic sort of the |P| ≤ |E| deltas is the hardware-native choice),
  3. drop every superedge with ΔRE ≤ Δ_ξ.

The module has two order-statistic backends (DESIGN.md §7):

  * ``jnp.sort`` for the single-host path (``further_sparsify``), and
  * :func:`radix_select_kth` — a bucketed/histogram selection over the
    order-preserving uint32 image of the float32 deltas — whose per-pass
    256-bin histogram can be ``psum``-ed across an edge-sharded mesh, so
    the distributed path finds the *exact* Δ_ξ without replicating or
    gathering the deltas.  All scalar inputs of the ξ computation
    (Size(Ḡ), |S|, |P|, ω_max) are exact integers-in-float32 under any
    reduction order, and Δ itself is computed from bit-identical (cnt, Π)
    on every path, so the resulting drop mask is bit-identical between the
    single-host sort and the distributed selection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.types import PairTable, SummaryState

# Radix passes over the 32-bit ordered key, most-significant first.
_RADIX_SHIFTS = (24, 16, 8, 0)
_RADIX_BINS = 256


def sparsify_deltas(cnt: jax.Array, pi: jax.Array, error_p: int) -> jax.Array:
    """Footnote-4 ΔRE_p of dropping each superedge (closed form).

    ``error_p == 2`` returns ΔRE₂² = |E_AB|²/|Π_AB| — same ordering as ΔRE₂.
    """
    sigma = cnt / jnp.maximum(pi, 1.0)
    if error_p == 1:
        return (2.0 * sigma - 1.0) * cnt
    return cnt * sigma


def sparsify_xi(
    size_bits: jax.Array,
    k_bits: float,
    num_supernodes: jax.Array,
    omega_max: jax.Array,
) -> jax.Array:
    """ξ — how many superedges must go to bring Size(Ḡ) within ``k_bits``.

    Each dropped superedge saves one per-superedge record of
    ``2log₂|S| + log₂ω_max`` bits (constant except the ω_max edge — paper
    note), so ξ = ⌈(Size(Ḡ) − k) / unit⌉.
    """
    s_count = jnp.maximum(num_supernodes, 2.0)
    w_max = jnp.maximum(omega_max, 2.0)
    unit = 2.0 * jnp.log2(s_count) + jnp.log2(w_max)
    over = jnp.maximum(size_bits - k_bits, 0.0)
    return jnp.ceil(over / unit).astype(jnp.int32)


def drop_from_threshold(
    keep: jax.Array,
    delta: jax.Array,
    delta_xi: jax.Array,
    xi: jax.Array,
    p_count: jax.Array,
) -> jax.Array:
    """Step 3: drop kept superedges with ΔRE ≤ Δ_ξ (plus the degenerate
    branch: when even dropping all |P| superedges cannot reach k, drop all).
    """
    drop = keep & (delta <= delta_xi) & (xi > 0)
    return jnp.where(xi >= p_count, keep, drop)


# ---------------------------------------------------------------------------
# Order-preserving float32 ↔ uint32 maps + histogram-bucketed selection
# ---------------------------------------------------------------------------


def ordered_key_from_f32(x: jax.Array) -> jax.Array:
    """Monotone injection float32 → uint32 (IEEE-754 total order trick):
    flip the sign bit of non-negatives, all bits of negatives."""
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    neg = u >= jnp.uint32(0x80000000)
    return jnp.where(neg, ~u, u | jnp.uint32(0x80000000))


def f32_from_ordered_key(key: jax.Array) -> jax.Array:
    """Inverse of :func:`ordered_key_from_f32`."""
    key = key.astype(jnp.uint32)
    neg = key < jnp.uint32(0x80000000)
    u = jnp.where(neg, ~key, key ^ jnp.uint32(0x80000000))
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def radix_select_kth(keys: jax.Array, valid: jax.Array, k: jax.Array,
                     reduce_hist=None) -> jax.Array:
    """The ``k``-th smallest (0-based) valid uint32 key, by 4 radix passes.

    Each pass histograms the next 8 bits of the keys still matching the
    resolved prefix and descends into the bucket containing rank ``k``.
    ``reduce_hist`` merges the int32[256] histogram across shards (e.g.
    ``lambda h: jax.lax.psum(h, axis)``); identity when None — this is the
    only cross-shard communication of the distributed selection: 4 psums of
    256 ints replace a replicated sort of |E| floats.

    Caller guarantees ``0 ≤ k < #valid``; out-of-range ranks return an
    unspecified key (the degenerate ξ branches never read it).
    """
    if reduce_hist is None:
        reduce_hist = lambda h: h
    keys = keys.astype(jnp.uint32)
    prefix = jnp.uint32(0)
    rank = k.astype(jnp.int32)
    for shift in _RADIX_SHIFTS:
        high_mask = jnp.uint32((0xFFFFFFFF << (shift + 8)) & 0xFFFFFFFF)
        active = valid & ((keys & high_mask) == (prefix & high_mask))
        digit = ((keys >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        hist = jnp.zeros((_RADIX_BINS,), jnp.int32).at[digit].add(
            jnp.where(active, 1, 0)
        )
        hist = reduce_hist(hist)
        cum = jnp.cumsum(hist)
        d = jnp.argmax(cum > rank).astype(jnp.int32)
        below = jnp.where(d > 0, cum[jnp.maximum(d - 1, 0)], 0)
        rank = rank - below
        prefix = prefix | (d.astype(jnp.uint32) << shift)
    return prefix


def select_delta_xi(delta: jax.Array, keep: jax.Array, xi: jax.Array,
                    reduce_hist=None) -> jax.Array:
    """Δ_ξ — the ξ-th smallest kept delta — via histogram selection.

    Returns the threshold as float32 so the ``delta ≤ Δ_ξ`` comparison runs
    in the float domain, exactly like the sort-based path.
    """
    keys = ordered_key_from_f32(delta)
    key_xi = radix_select_kth(keys, keep, jnp.maximum(xi - 1, 0), reduce_hist)
    return f32_from_ordered_key(key_xi)


# ---------------------------------------------------------------------------
# Single-host driver (sort-based order statistic)
# ---------------------------------------------------------------------------


def further_sparsify(
    pt: PairTable,
    state: SummaryState,
    num_nodes: int,
    num_edges: int,
    k_bits: float,
    cbar_mode: str = "tight",
    re_guard: int = 1,
    error_p: int = 1,
):
    """Compute the drop mask that brings Size(Ḡ) within ``k_bits``.

    Returns ``(drop_mask bool[E], metrics_after dict)``.
    """
    metrics = costs.summary_metrics(
        pt, state, num_nodes, num_edges, cbar_mode=cbar_mode, re_guard=re_guard
    )
    keep = metrics["keep"]
    pi = costs.pair_pi(pt, state.size)
    delta = sparsify_deltas(pt.cnt, pi, error_p)
    xi = sparsify_xi(
        metrics["size_bits"], k_bits, metrics["num_supernodes"],
        metrics["omega_max"],
    )

    masked = jnp.where(keep, delta, jnp.inf)
    order = jnp.sort(masked)
    p_count = metrics["num_superedges"].astype(jnp.int32)
    xi_idx = jnp.clip(xi - 1, 0, masked.shape[0] - 1)
    delta_xi = order[xi_idx]
    drop = drop_from_threshold(keep, delta, delta_xi, xi, p_count)

    after = costs.summary_metrics(
        pt,
        state,
        num_nodes,
        num_edges,
        cbar_mode=cbar_mode,
        re_guard=re_guard,
        drop_mask=drop,
    )
    return drop, after
