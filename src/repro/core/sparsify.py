"""Further sparsification (Sect. 3.2.4): drop superedges until Size(Ḡ) ≤ k.

Vectorized version of the paper's three steps:
  1. closed-form RE_p increase per kept superedge (footnote 4):
         ΔRE₁ = (2|E_AB|/|Π_AB| - 1)·|E_AB|      ΔRE₂² = |E_AB|²/|Π_AB|
  2. the ξ-th smallest increase Δ_ξ via an order statistic
     (``jnp.sort`` — the paper uses median-of-medians selection; on TPU a
     bitonic sort of the |P| ≤ |E| deltas is the hardware-native choice),
  3. drop every superedge with ΔRE ≤ Δ_ξ.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import costs
from repro.core.types import PairTable, SummaryState


def further_sparsify(
    pt: PairTable,
    state: SummaryState,
    num_nodes: int,
    num_edges: int,
    k_bits: float,
    cbar_mode: str = "tight",
    re_guard: int = 1,
    error_p: int = 1,
):
    """Compute the drop mask that brings Size(Ḡ) within ``k_bits``.

    Returns ``(drop_mask bool[E], metrics_after dict)``.
    """
    metrics = costs.summary_metrics(
        pt, state, num_nodes, num_edges, cbar_mode=cbar_mode, re_guard=re_guard
    )
    keep = metrics["keep"]
    pi = costs.pair_pi(pt, state.size)
    sigma = pt.cnt / jnp.maximum(pi, 1.0)
    if error_p == 1:
        delta = (2.0 * sigma - 1.0) * pt.cnt
    else:
        delta = pt.cnt * sigma  # ΔRE₂² — same ordering as ΔRE₂

    # per-superedge storage cost (constant except the ω_max edge — paper note)
    s_count = jnp.maximum(metrics["num_supernodes"], 2.0)
    w_max = jnp.maximum(metrics["omega_max"], 2.0)
    unit = 2.0 * jnp.log2(s_count) + jnp.log2(w_max)
    over = jnp.maximum(metrics["size_bits"] - k_bits, 0.0)
    xi = jnp.ceil(over / unit).astype(jnp.int32)

    masked = jnp.where(keep, delta, jnp.inf)
    order = jnp.sort(masked)
    p_count = metrics["num_superedges"].astype(jnp.int32)
    xi_idx = jnp.clip(xi - 1, 0, masked.shape[0] - 1)
    delta_xi = order[xi_idx]
    drop = keep & (delta <= delta_xi) & (xi > 0)
    # degenerate case: dropping everything still can't reach k
    drop = jnp.where(xi >= p_count, keep, drop)

    after = costs.summary_metrics(
        pt,
        state,
        num_nodes,
        num_edges,
        cbar_mode=cbar_mode,
        re_guard=re_guard,
        drop_mask=drop,
    )
    return drop, after
