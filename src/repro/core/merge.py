"""Merging & sparsification phase (Sect. 3.2.3, Alg. 2) — TPU-native form.

One outer iteration = one *parallel coarsening round*: every candidate group
scores all of its pairs with the Pallas merge-gain kernel and merges a
maximal set of mutually-best pairs whose Relative_Reduction (Eq. 20) exceeds
the annealing threshold θ(t) (Eq. 21). Superedge sparsification is implicit:
the optimal encoding P*(S) is recomputed in closed form whenever costs or
sizes are evaluated (Eq. 11), which is exactly the paper's "add superedges
selectively so that the cost is minimized" step.

Deviation from the sequential paper loop (DESIGN.md §3 ⚠): instead of
merging repeatedly inside one group while others wait, all groups across the
whole graph merge one matching simultaneously; the T outer iterations with
re-randomized shingles provide the repeated chances the sequential loop gets
within an iteration. Matching via mutual-argmax guarantees the merge set is
disjoint, so applying it is a single gather.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costs, shingles, tables
from repro.core.types import SummaryConfig, SummaryState
from repro.kernels import ops as kops


def theta_schedule(t: jax.Array, big_t: int) -> jax.Array:
    """Eq. (21): θ(t) = (1+t)⁻¹ for t < T, 0 at t ≥ T."""
    return jnp.where(t < big_t, 1.0 / (1.0 + t.astype(jnp.float32)), 0.0)


def select_matching(
    rel: jax.Array,  # f32[G, C, C]
    members: jax.Array,  # i32[G, C]
    theta: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Mutually-best pairs above θ → disjoint merge list (a_ids, b_ids, sel)."""
    g, c, _ = rel.shape
    best_j = jnp.argmax(rel, axis=-1).astype(jnp.int32)  # [G, C]
    best_v = jnp.max(rel, axis=-1)  # [G, C]
    idx = jnp.arange(c, dtype=jnp.int32)[None, :]
    partner_best = jnp.take_along_axis(best_j, best_j, axis=1)
    mutual = partner_best == idx
    accept = mutual & (best_v > theta) & (idx < best_j)
    a = jnp.take_along_axis(members, idx, axis=1)
    b = jnp.take_along_axis(members, best_j, axis=1)
    accept = accept & (a >= 0) & (b >= 0)
    return a.reshape(-1), b.reshape(-1), accept.reshape(-1)


def apply_merges(
    state: SummaryState, a: jax.Array, b: jax.Array, sel: jax.Array
) -> tuple[SummaryState, jax.Array]:
    """Union each selected pair: supernode ``b`` is absorbed into ``a``."""
    v = state.node2super.shape[0]
    b_idx = jnp.where(sel, b, v)  # OOB → dropped
    a_idx = jnp.where(sel, a, v)
    parent = jnp.arange(v, dtype=jnp.int32).at[b_idx].set(
        jnp.where(sel, a, 0), mode="drop"
    )
    node2super = parent[state.node2super]
    moved = jnp.where(sel, state.size[jnp.minimum(b, v - 1)], 0)
    size = state.size.at[a_idx].add(moved, mode="drop")
    size = size.at[b_idx].set(0, mode="drop")
    nmerges = jnp.sum(sel.astype(jnp.int32))
    return (
        SummaryState(node2super=node2super, size=size, rng=state.rng, t=state.t),
        nmerges,
    )


def merge_iteration(
    src: jax.Array,
    dst: jax.Array,
    state: SummaryState,
    cfg: SummaryConfig,
    theta: jax.Array,
) -> tuple[SummaryState, dict[str, jax.Array]]:
    """One full candidate-generation + merging round (Alg. 1 lines 5–7)."""
    v = state.node2super.shape[0]
    e = src.shape[0]
    rng, k_groups = jax.random.split(state.rng)
    state = SummaryState(
        node2super=state.node2super, size=state.size, rng=rng, t=state.t
    )

    pt = costs.build_pair_table(src, dst, state)
    metrics = costs.summary_metrics(
        pt, state, v, e, cbar_mode=cfg.cbar_mode, re_guard=cfg.re_guard
    )
    cbar = metrics["cbar"]
    log2v = jnp.log2(jnp.float32(v))

    groups = shingles.build_groups(src, dst, state, k_groups, cfg.group_size)
    gt = tables.build_group_tables(
        pt, state, groups, cfg.max_neighbors, cfg.union_size, cbar, v
    )
    rel, red = kops.merge_gain(
        gt.m,
        gt.n,
        gt.s,
        gt.t,
        gt.n_u,
        gt.cidx,
        gt.w,
        cbar,
        log2v,
        backend=kops.resolve_kernel_backend(cfg.kernel_backend),
    )
    a, b, sel = select_matching(rel, gt.members, theta)
    new_state, nmerges = apply_merges(state, a, b, sel)
    # summed Eq. 20 absolute reduction (bits) of the accepted pairs: gather
    # each row's best-partner red — the same argmax select_matching used
    best_j = jnp.argmax(rel, axis=-1)
    red_best = jnp.take_along_axis(red, best_j[..., None], axis=-1)[..., 0]
    total_reduction = jnp.sum(jnp.where(sel, red_best.reshape(-1), 0.0))
    new_state = SummaryState(
        node2super=new_state.node2super,
        size=new_state.size,
        rng=new_state.rng,
        t=state.t + 1,
    )
    stats = {
        "nmerges": nmerges,
        "size_bits": metrics["size_bits"],
        "mdl_cost": metrics["mdl_cost"],
        "re1": metrics["re1"],
        "re2": metrics["re2"],
        "num_supernodes": metrics["num_supernodes"],
        "num_superedges": metrics["num_superedges"],
        "total_reduction": total_reduction,
    }
    return new_state, stats
