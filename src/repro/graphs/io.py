"""Streaming edge-list ingestion: SNAP text → binary CSR cache → mmap load.

The paper's scale claims live or die on ingestion: a 783M-edge web graph
cannot be parsed into python lists, so this module reads SNAP-format text
(``.txt``/``.csv``, ``#``/``%`` comments, optional gzip) in fixed-size
chunks, canonicalizes each chunk (undirected ``lo < hi``, self-loops
stripped), spills sorted unique runs to disk, and k-way block-merges the
runs into a deduplicated canonical edge list — peak RSS is bounded by the
chunk size (plus an O(|V|) id table), never by |E|. The result is
materialized once as a binary cache directory of ``.npy`` files (canonical
edge arrays + a symmetrized CSR) that later loads open with
``np.load(..., mmap_mode="r")`` in O(1). See DESIGN.md §10.

Node-id relabeling is deterministic: ids are mapped to a dense contiguous
range by *sorted original id*, so a file whose ids are already
``0..V-1``-dense loads with identity labels — this is what makes the
``--edge-list`` path bit-identical to the in-memory ``generate`` path on
the same edge set. A SNAP ``# Nodes: <n> Edges: <m>`` header is honored:
when every observed id is ``< n`` the loader keeps original labels and
``num_nodes = n`` (preserving isolated nodes, which edge lists cannot
otherwise express); ids outside the header range fall back to relabeling.

Dataset resolution order (``load_graph``): real file under
``$SSUMM_DATA_DIR`` → binary cache → synthetic stand-in (``generate``).

Downstream, the cache is the hand-off point of the out-of-core data path:
:mod:`repro.graphs.feed` slices the mmap'd ``src``/``dst`` members into
per-device shards without re-densifying — DESIGN.md §11 walks the whole
file → spill → cache → feed → shard_map pipeline with its memory model.
"""

from __future__ import annotations

import dataclasses
import gzip
import json
import os
import re
import shutil
import tempfile

import numpy as np

from repro.graphs import synthetic

DATA_DIR_ENV = "SSUMM_DATA_DIR"
CACHE_DIR_ENV = "SSUMM_CACHE_DIR"
CHUNK_EDGES_ENV = "SSUMM_CHUNK_EDGES"

CACHE_SUFFIX = ".ssummcache"
CACHE_VERSION = 1
# every member a fresh cache must carry; a cache that lost one (e.g. a
# mid-write crash between the staging swap and a later manual cleanup)
# is treated as absent and re-ingested rather than raising downstream
CACHE_MEMBERS = ("src.npy", "dst.npy", "indptr.npy", "indices.npy")
DEFAULT_CHUNK_EDGES = 1 << 20
_EXTS = (".txt", ".txt.gz", ".csv", ".csv.gz", ".el", ".el.gz")
# raw ids pack two-per-*signed*-int64 during the merge and land in int32
# arrays after relabeling, so the raw-id ceiling is 2^31 (covers every
# SNAP dataset in Table 2; web-uk-05 has |V| ≈ 39M)
_ID_LIMIT = 1 << 31

_HEADER_RE = re.compile(r"Nodes:\s*(\d+)")


@dataclasses.dataclass
class IngestStats:
    """Parser-side accounting (``bytes_parsed == 0`` ⇔ pure cache hit)."""

    bytes_parsed: int = 0
    lines_parsed: int = 0
    comment_lines: int = 0
    edges_raw: int = 0
    self_loops_dropped: int = 0
    duplicates_dropped: int = 0
    chunks: int = 0
    max_chunk_rows: int = 0
    spill_runs: int = 0
    relabeled: bool = False
    header_nodes: int | None = None

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LoadedGraph:
    """A canonical graph plus where it came from (``real|cache|synthetic``)."""

    src: np.ndarray  # int32[E], src < dst, unique, sorted by (src, dst)
    dst: np.ndarray  # int32[E]
    num_nodes: int
    source: str
    path: str | None  # source text file (real) or None
    cache_dir: str | None
    stats: IngestStats

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])


# ---------------------------------------------------------------------------
# Chunked text parsing
# ---------------------------------------------------------------------------


def _open_text(path: str):
    if path.endswith(".gz"):
        return gzip.open(path, "rt", encoding="utf-8", errors="replace")
    return open(path, "rt", encoding="utf-8", errors="replace")


def iter_edge_chunks(path: str, chunk_edges: int, stats: IngestStats):
    """Yield ``(src, dst)`` int64 chunk arrays of ≲ ``chunk_edges`` rows.

    Comment lines (``#``/``%``) are counted and skipped; a SNAP
    ``# Nodes: <n>`` header is recorded in ``stats.header_nodes``. Commas
    are treated as whitespace so ``.csv`` parses identically; rows with
    extra columns (weights, timestamps) keep their first two fields.
    """
    sizehint = max(chunk_edges, 1) * 24  # ~bytes per SNAP line
    with _open_text(path) as f:
        while True:
            lines = f.readlines(sizehint)
            if not lines:
                return
            stats.bytes_parsed += sum(len(ln) for ln in lines)
            stats.lines_parsed += len(lines)
            data = []
            for ln in lines:
                s = ln.strip()
                if not s:
                    continue
                if s[0] in "#%":
                    stats.comment_lines += 1
                    if stats.header_nodes is None:
                        m = _HEADER_RE.search(s)
                        if m:
                            stats.header_nodes = int(m.group(1))
                    continue
                data.append(s.replace(",", " "))
            if not data:
                continue
            # split per line (an aggregate token count can silently mispair
            # fields across rows with mixed column counts); rows with extra
            # columns — weights, timestamps — keep their first two fields
            pairs = [ln.split(None, 3) for ln in data]
            bad = next((p for p in pairs if len(p) < 2), None)
            if bad is not None:
                raise ValueError(f"{path}: malformed edge line {bad!r} "
                                 f"(need two node ids)")
            arr = np.array([p[:2] for p in pairs], dtype=np.int64)
            if arr.size and (arr.min() < 0 or arr.max() >= _ID_LIMIT):
                raise ValueError(
                    f"{path}: node ids must be in [0, 2^31); "
                    f"got range [{arr.min()}, {arr.max()}]")
            stats.edges_raw += arr.shape[0]
            stats.chunks += 1
            stats.max_chunk_rows = max(stats.max_chunk_rows, arr.shape[0])
            yield arr[:, 0], arr[:, 1]


# ---------------------------------------------------------------------------
# External merge of sorted unique runs (bounded memory)
# ---------------------------------------------------------------------------


def _spill_runs(path: str, chunk_edges: int, workdir: str,
                stats: IngestStats) -> list[str]:
    """Canonicalize each chunk and spill it as a sorted unique key run."""
    runs = []
    for src, dst in iter_edge_chunks(path, chunk_edges, stats):
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        keep = lo != hi
        stats.self_loops_dropped += int((~keep).sum())
        keys = np.unique((lo[keep] << np.int64(32)) | hi[keep])
        stats.duplicates_dropped += int(keep.sum()) - keys.size
        if keys.size == 0:
            continue
        run = os.path.join(workdir, f"run{len(runs):05d}.npy")
        np.save(run, keys)
        runs.append(run)
    stats.spill_runs = len(runs)
    return runs


def _merge_runs(runs: list[str], out_path: str, block: int) -> int:
    """K-way block-merge the sorted runs into ``out_path`` (raw int64),
    dropping cross-run duplicates. Returns the number of unique keys.

    Per round: every active run exposes its next ≤``block`` keys; the
    cut is the smallest block-end value, so each run's block provably
    contains *all* of its keys ≤ cut — those prefixes merge with one
    concatenate+unique of ≤ ``len(runs)·block`` elements.
    """
    mms = [np.load(r, mmap_mode="r") for r in runs]
    pos = [0] * len(mms)
    total = 0
    prev_last: int | None = None
    with open(out_path, "wb") as out:
        while True:
            ends = [
                mm[min(p + block, len(mm)) - 1]
                for mm, p in zip(mms, pos) if p < len(mm)
            ]
            if not ends:
                break
            cut = min(ends)
            parts = []
            for i, mm in enumerate(mms):
                if pos[i] >= len(mm):
                    continue
                blk = mm[pos[i]:pos[i] + block]
                take = int(np.searchsorted(blk, cut, side="right"))
                if take:
                    parts.append(np.asarray(blk[:take]))
                    pos[i] += take
            merged = np.unique(np.concatenate(parts))
            if prev_last is not None and merged.size and merged[0] == prev_last:
                merged = merged[1:]  # boundary duplicate across rounds
            if merged.size:
                prev_last = int(merged[-1])
                merged.tofile(out)
                total += merged.size
    return total


# ---------------------------------------------------------------------------
# Cache materialization (canonical edges + symmetrized CSR)
# ---------------------------------------------------------------------------


def _file_stamp(path: str) -> dict:
    st = os.stat(path)
    return {"size": st.st_size, "mtime_ns": st.st_mtime_ns,
            "name": os.path.basename(path)}


def _blocks(n: int, block: int):
    for start in range(0, n, block):
        yield start, min(start + block, n)


def _write_cache(keys_path: str, n_edges: int, cache_dir: str,
                 source_path: str, chunk_edges: int,
                 stats: IngestStats) -> None:
    """Turn the merged key stream into the final ``.npy`` cache files."""
    block = max(chunk_edges, 1024)
    keys = np.memmap(keys_path, dtype=np.int64, mode="r", shape=(n_edges,)) \
        if n_edges else np.zeros((0,), np.int64)

    # id table: header-identity when every id < header's |V|, else dense
    # relabel by sorted original id (deterministic, chunk-independent).
    # Per-block uniques accumulate and collapse only when the pending pile
    # outgrows the table (amortized doubling) — O(log) collapses instead
    # of one O(|V| log |V|) union per block.
    max_id = -1
    ids = np.zeros((0,), np.int64)
    pend: list[np.ndarray] = []
    pend_n = 0
    for a, b in _blocks(n_edges, block):
        k = np.asarray(keys[a:b])
        if not k.size:
            continue
        lo, hi = k >> np.int64(32), k & np.int64(0xFFFFFFFF)
        max_id = max(max_id, int(hi.max()), int(lo.max()))
        u = np.unique(np.concatenate([lo, hi]))
        pend.append(u)
        pend_n += u.size
        if pend_n >= max(ids.size, block):
            ids = np.union1d(ids, np.concatenate(pend))
            pend, pend_n = [], 0
    if pend:
        ids = np.union1d(ids, np.concatenate(pend))
        del pend
    header = stats.header_nodes
    if header is not None and max_id < min(header, 1 << 31):
        v, relabel = int(header), None
    elif n_edges == 0:
        v, relabel = (int(header) if header is not None else 0), None
    else:
        v, relabel = int(ids.size), ids
    stats.relabeled = relabel is not None

    # stage in a per-build private dir (concurrent ingests of the same
    # file must not clobber each other's half-written staging area)
    parent = os.path.dirname(os.path.abspath(cache_dir)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=os.path.basename(cache_dir) + ".tmp",
                           dir=parent)
    try:
        _fill_cache_arrays(tmp, keys, n_edges, v, relabel, block)
        meta = {
            "version": CACHE_VERSION,
            "num_nodes": v,
            "num_edges": n_edges,
            "relabeled": relabel is not None,
            "source": _file_stamp(source_path),
            "stats": stats.asdict(),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    shutil.rmtree(cache_dir, ignore_errors=True)
    try:
        os.replace(tmp, cache_dir)
    except OSError:
        # a concurrent build of the same file won the swap; its cache is
        # byte-identical (the build is deterministic), so keep it
        shutil.rmtree(tmp, ignore_errors=True)


def _fill_cache_arrays(tmp: str, keys, n_edges: int, v: int,
                       relabel, block: int) -> None:
    """Write src/dst/indptr/indices ``.npy`` into ``tmp`` in row-aligned
    blocks (all memmap-backed; nothing O(|E|) in memory)."""
    src_mm = np.lib.format.open_memmap(
        os.path.join(tmp, "src.npy"), mode="w+", dtype=np.int32,
        shape=(n_edges,))
    dst_mm = np.lib.format.open_memmap(
        os.path.join(tmp, "dst.npy"), mode="w+", dtype=np.int32,
        shape=(n_edges,))
    deg = np.zeros((v,), np.int64)
    for a, b in _blocks(n_edges, block):
        k = np.asarray(keys[a:b])
        lo, hi = k >> np.int64(32), k & np.int64(0xFFFFFFFF)
        if relabel is not None:
            lo = np.searchsorted(relabel, lo)
            hi = np.searchsorted(relabel, hi)
        src_mm[a:b] = lo.astype(np.int32)
        dst_mm[a:b] = hi.astype(np.int32)
        deg += np.bincount(lo, minlength=v) + np.bincount(hi, minlength=v)

    indptr = np.zeros((v + 1,), np.int64)
    np.cumsum(deg, out=indptr[1:])
    np.save(os.path.join(tmp, "indptr.npy"), indptr)
    indices = np.lib.format.open_memmap(
        os.path.join(tmp, "indices.npy"), mode="w+", dtype=np.int32,
        shape=(2 * n_edges,))
    next_free = indptr[:-1].copy()
    for a, b in _blocks(n_edges, block):
        rows = np.concatenate([src_mm[a:b], dst_mm[a:b]]).astype(np.int64)
        cols = np.concatenate([dst_mm[a:b], src_mm[a:b]])
        order = np.argsort(rows, kind="stable")
        r, c = rows[order], cols[order]
        uniq, first, counts = np.unique(r, return_index=True,
                                        return_counts=True)
        offs = np.arange(r.size, dtype=np.int64) - np.repeat(first, counts)
        indices[np.repeat(next_free[uniq], counts) + offs] = c
        next_free[uniq] += counts
    # sort neighbors within each row (bounded-memory pass over row-aligned
    # segments) — also makes the cache independent of the chunk size, which
    # would otherwise leak into the lo-side/hi-side interleaving order
    start_row = 0
    while start_row < v:
        end_row = int(np.searchsorted(indptr, indptr[start_row] + 2 * block,
                                      side="left"))
        end_row = min(max(end_row, start_row + 1), v)
        s, e = int(indptr[start_row]), int(indptr[end_row])
        if e > s:
            seg = np.asarray(indices[s:e], np.int64)
            rows = np.repeat(
                np.arange(start_row, end_row, dtype=np.int64),
                np.diff(indptr[start_row:end_row + 1]))
            order = np.argsort(rows * v + seg, kind="stable")
            indices[s:e] = seg[order].astype(np.int32)
        start_row = end_row
    src_mm.flush(); dst_mm.flush(); indices.flush()
    del src_mm, dst_mm, indices


def default_cache_dir(path: str) -> str:
    root = os.environ.get(CACHE_DIR_ENV)
    if root:
        return os.path.join(root, os.path.basename(path) + CACHE_SUFFIX)
    return path + CACHE_SUFFIX


def _chunk_edges_default(chunk_edges: int | None) -> int:
    if chunk_edges is not None:
        return int(chunk_edges)
    return int(os.environ.get(CHUNK_EDGES_ENV, DEFAULT_CHUNK_EDGES))


def ingest_edge_list(path: str, cache_dir: str | None = None,
                     chunk_edges: int | None = None) -> str:
    """Parse ``path`` once and materialize its binary cache; returns the
    cache directory. Peak memory ~ O(chunk_edges + |V|), never O(|E|)."""
    cache_dir = cache_dir or default_cache_dir(path)
    chunk_edges = _chunk_edges_default(chunk_edges)
    stats = IngestStats()
    workdir = tempfile.mkdtemp(prefix="ssumm-ingest-")
    try:
        runs = _spill_runs(path, chunk_edges, workdir, stats)
        keys_path = os.path.join(workdir, "merged.keys")
        if len(runs) == 1:
            # single run: already sorted unique — link it in place
            np.load(runs[0], mmap_mode="r")[:].tofile(keys_path)
            n = np.load(runs[0], mmap_mode="r").shape[0]
        elif runs:
            # split the chunk budget across runs so the per-round concat
            # stays ≤ ~chunk_edges elements regardless of run count
            n = _merge_runs(runs, keys_path,
                            block=max(chunk_edges // len(runs), 1024))
        else:
            open(keys_path, "wb").close()
            n = 0
        # duplicates dropped across chunks = spilled total − merged total
        spilled = sum(np.load(r, mmap_mode="r").shape[0] for r in runs)
        stats.duplicates_dropped += spilled - n
        _write_cache(keys_path, n, cache_dir, path, chunk_edges, stats)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return cache_dir


def _member_is_intact(path: str, dtype: np.dtype, shape: tuple) -> bool:
    """True iff ``path`` is a complete ``.npy`` of exactly dtype/shape.

    Reads only the npy header (a few hundred bytes), then checks the file
    size equals header + payload — a blob truncated by a crashed or killed
    writer is caught here without paging in any data."""
    readers = {(1, 0): np.lib.format.read_array_header_1_0,
               (2, 0): np.lib.format.read_array_header_2_0}
    try:
        with open(path, "rb") as f:
            version = np.lib.format.read_magic(f)
            reader = readers.get(tuple(version))
            if reader is None:
                return False
            got_shape, fortran, got_dtype = reader(f)
            data_start = f.tell()
    except (OSError, ValueError):
        return False
    if fortran or got_dtype != dtype or tuple(got_shape) != tuple(shape):
        return False
    expect = data_start + int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return os.path.getsize(path) == expect


def _expected_members(meta: dict) -> dict[str, tuple[np.dtype, tuple]]:
    """dtype/shape of every cache member, derived from meta.json counts."""
    v, e = int(meta["num_nodes"]), int(meta["num_edges"])
    return {
        "src.npy": (np.dtype(np.int32), (e,)),
        "dst.npy": (np.dtype(np.int32), (e,)),
        "indptr.npy": (np.dtype(np.int64), (v + 1,)),
        "indices.npy": (np.dtype(np.int32), (2 * e,)),
    }


def cache_is_fresh(cache_dir: str, source_path: str | None = None) -> bool:
    """A cache is fresh iff meta.json parses, matches the source stamp,
    and **all four** ``.npy`` members are intact — present, with the
    dtype/shape meta.json implies, and byte-complete on disk. A directory
    that lost a member or holds a truncated blob (mid-write crash, partial
    copy, disk-full) must fall through to re-ingestion instead of raising
    (or worse, mmap-ing zeros) at ``np.load`` time."""
    meta_path = os.path.join(cache_dir, "meta.json")
    if not os.path.exists(meta_path):
        return False
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return False
    if meta.get("version") != CACHE_VERSION:
        return False
    try:
        expected = _expected_members(meta)
    except (KeyError, TypeError, ValueError):
        return False
    assert set(expected) == set(CACHE_MEMBERS)
    for member, (dtype, shape) in expected.items():
        if not _member_is_intact(os.path.join(cache_dir, member),
                                 dtype, shape):
            return False
    if source_path is not None and os.path.exists(source_path):
        if meta.get("source") != _file_stamp(source_path):
            return False
    return True


def load_cache(cache_dir: str, source: str = "cache",
               path: str | None = None) -> LoadedGraph:
    """O(1) load: ``.npy`` arrays open with ``mmap_mode="r"``, 0 bytes
    of text are parsed (``stats.bytes_parsed == 0``)."""
    with open(os.path.join(cache_dir, "meta.json")) as f:
        meta = json.load(f)
    stats = IngestStats(relabeled=bool(meta.get("relabeled", False)),
                        header_nodes=meta.get("stats", {}).get("header_nodes"))
    return LoadedGraph(
        src=np.load(os.path.join(cache_dir, "src.npy"), mmap_mode="r"),
        dst=np.load(os.path.join(cache_dir, "dst.npy"), mmap_mode="r"),
        num_nodes=int(meta["num_nodes"]),
        source=source, path=path, cache_dir=cache_dir, stats=stats,
    )


def open_csr(cache_dir: str) -> tuple[np.ndarray, np.ndarray]:
    """The symmetrized CSR adjacency (``indptr`` int64[V+1], ``indices``
    int32[2E], mmap'd; neighbors sorted ascending within each row)."""
    return (np.load(os.path.join(cache_dir, "indptr.npy"), mmap_mode="r"),
            np.load(os.path.join(cache_dir, "indices.npy"), mmap_mode="r"))


# ---------------------------------------------------------------------------
# Registry resolution: real file → cache → synthetic
# ---------------------------------------------------------------------------


def find_real_file(name: str, data_dir: str | None = None) -> str | None:
    data_dir = data_dir or os.environ.get(DATA_DIR_ENV)
    if not data_dir:
        return None
    for ext in _EXTS:
        p = os.path.join(data_dir, name + ext)
        if os.path.exists(p):
            return p
    return None


def load_graph(name_or_path: str, *, data_dir: str | None = None,
               cache_dir: str | None = None, chunk_edges: int | None = None,
               refresh: bool = False, scale: float = 1.0,
               seed: int = 0) -> LoadedGraph:
    """Resolve a Table-2 name or an explicit edge-list path to a graph.

    Priority: real file (``$SSUMM_DATA_DIR`` or the path itself) → its
    binary cache (if fresh; re-ingested otherwise) → synthetic stand-in
    (registry names only; ``scale``/``seed`` apply there and only there).
    ``refresh=True`` forces a re-parse even when the cache is fresh.
    """
    path = name_or_path if os.path.exists(name_or_path) else \
        find_real_file(name_or_path, data_dir)
    if path is not None:
        cdir = cache_dir or default_cache_dir(path)
        if refresh or not cache_is_fresh(cdir, path):
            cdir = ingest_edge_list(path, cdir, chunk_edges)
            g = load_cache(cdir, source="real", path=path)
            # surface the parse-side accounting of the ingest we just did
            with open(os.path.join(cdir, "meta.json")) as f:
                g.stats = IngestStats(**json.load(f)["stats"])
            return g
        return load_cache(cdir, source="cache", path=path)
    # no source file: a cache built earlier may still serve the name.
    # Ingest names caches `<basename-with-extension>.ssummcache`, so probe
    # every extension variant under $SSUMM_CACHE_DIR and the data dir.
    roots = [r for r in (os.environ.get(CACHE_DIR_ENV),
                         data_dir or os.environ.get(DATA_DIR_ENV)) if r]
    candidates = [cache_dir] if cache_dir else [
        os.path.join(root, name_or_path + ext + CACHE_SUFFIX)
        for root in roots for ext in ("",) + _EXTS]
    for cdir in candidates:
        if cache_is_fresh(cdir):
            return load_cache(cdir)
    if name_or_path in synthetic.DATASETS:
        src, dst, v = synthetic.generate(name_or_path, seed=seed, scale=scale)
        return LoadedGraph(src=np.asarray(src, np.int32),
                           dst=np.asarray(dst, np.int32), num_nodes=v,
                           source="synthetic", path=None, cache_dir=None,
                           stats=IngestStats())
    raise FileNotFoundError(
        f"{name_or_path!r}: not a file, not under ${DATA_DIR_ENV}, no cache, "
        f"and not a registry dataset ({', '.join(sorted(synthetic.DATASETS))})")


# ---------------------------------------------------------------------------
# Deterministic SNAP-text writer (fixtures / CI; scripts/make_edgelist.py)
# ---------------------------------------------------------------------------


def write_edge_list(path: str, src, dst, num_nodes: int, *,
                    seed: int = 0, shuffle: bool = False,
                    one_indexed: bool = False, dup_frac: float = 0.0,
                    self_loops: int = 0, header: bool = True,
                    comment: str | None = None,
                    block_lines: int = 1 << 16) -> str:
    """Emit an edge list as SNAP text (gzip when ``path`` ends in ``.gz``,
    comma-separated when it contains ``.csv``). Deterministic in ``seed``.

    ``shuffle`` permutes edge order and flips random edge directions;
    ``dup_frac`` re-appends that fraction of edges; ``self_loops`` appends
    loops — all noise the streaming loader must normalize away.
    """
    rng = np.random.default_rng(seed)
    src = np.asarray(src, np.int64).copy()
    dst = np.asarray(dst, np.int64).copy()
    if dup_frac > 0.0 and src.size:
        n_dup = int(src.size * dup_frac)
        idx = rng.integers(0, src.size, n_dup)
        src = np.concatenate([src, src[idx]])
        dst = np.concatenate([dst, dst[idx]])
    if self_loops > 0:
        loops = rng.integers(0, max(num_nodes, 1), self_loops)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    if shuffle and src.size:
        perm = rng.permutation(src.size)
        src, dst = src[perm], dst[perm]
        flip = rng.random(src.size) < 0.5
        src, dst = np.where(flip, dst, src), np.where(flip, src, dst)
    if one_indexed:
        src, dst = src + 1, dst + 1
    sep = "," if ".csv" in os.path.basename(path) else "\t"
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "wt", encoding="utf-8") as f:
        if comment:
            f.write(f"# {comment}\n")
        if header:
            f.write(f"# Nodes: {num_nodes} Edges: {src.size}\n")
        for a, b in _blocks(int(src.size), block_lines):
            f.write("\n".join(
                f"{s}{sep}{d}" for s, d in zip(src[a:b], dst[a:b])) + "\n")
    return path
