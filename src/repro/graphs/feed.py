"""Out-of-core shard feeding: mmap'd CSR cache → per-device edge shards.

The paper's scalability claim (26× larger graphs, linear scaling) dies on
the host long before it dies on the accelerators if the driver re-packs
the full edge list into host arrays just to shard it. This module is the
zero-densify bridge from the binary CSR cache (:mod:`repro.graphs.io`,
DESIGN.md §10) to the edge-sharded shard_map pipeline
(:mod:`repro.core.distributed`, DESIGN.md §7): the mmap'd ``src``/``dst``
arrays are sliced into ``n_dev`` contiguous, ``-1``-padded shards, one
shard-sized staging buffer at a time — at no point is a full-|E| host
array materialized. See DESIGN.md §11 for the end-to-end data path
(file → sorted-run spill → CSR cache → per-shard feed → shard_map) and
its memory-model table.

Three entry points build the same sharded ``jax.Array`` pair:

* :func:`shard_edges_from_cache` — slices the cache's mmap'd ``.npy``
  members directly (peak host staging = one shard; the mmap'd pages are
  ``madvise(DONTNEED)``-ed after the feed, so even page-cache residency
  is transient);
* :func:`shard_edges` — in-memory fallback for edge lists that already
  live in host arrays (synthetic registry graphs); it subsumes the old
  ``pad_and_shard_edges`` and produces **bit-identical** shard contents,
  so the two paths are interchangeable down to the psum'd Eq.(2)/(4)
  metrics (asserted by ``tests/feed_check.py``);
* :func:`shard_edges_from_cache_multihost` — the process-spanning-mesh
  variant of the cache feed: each process stages only the shards its
  local devices own (DESIGN.md §15). The two single-process entry points
  refuse process-spanning meshes and point here.

Both fill each shard into the staging buffer, ``device_put`` it onto its
device, and assemble the global array with
``jax.make_array_from_single_device_arrays`` — the result is *born* with
the ``summarize``-mode edge sharding (``MeshRules.edge_spec``), so
``jit``-ing the shard_map'd step never inserts a gather-and-reshard.
:class:`FeedStats` records the exact staging high-water mark; the CI
``ingest`` job asserts ``peak_staging_bytes`` never approaches 4·|E|.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.dist import make_rules
from repro.graphs import io as graph_io


@dataclasses.dataclass
class FeedStats:
    """Host-side accounting of one feed (``asdict`` lands in driver JSON).

    ``peak_staging_bytes`` is the high-water mark of host memory this
    module allocated to stage shards — by construction ≤ one shard
    (``shard_bytes``), never 4·|E|. ``bytes_copied`` counts what actually
    moved host→device (both columns, padding included).
    """

    num_edges: int = 0
    padded_edges: int = 0
    n_devices: int = 0
    shard_rows: int = 0
    shard_bytes: int = 0
    peak_staging_bytes: int = 0
    bytes_copied: int = 0
    path: str = "memory"  # "cache-mmap" | "memory" | "cache-mmap-multihost"
    process_count: int = 1
    local_shards: int = 0  # shards this process staged (== n_devices when 1 proc)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class EdgeShards:
    """Sharded padded edge columns plus provenance/accounting.

    ``src``/``dst`` are global ``jax.Array``s of shape ``[padded]``
    (``padded = |E| + (−|E| mod n_dev)``, ``-1`` in the padded slots),
    sharded contiguously over every mesh axis (``MeshRules.edge_spec``).
    """

    src: jax.Array
    dst: jax.Array
    num_edges: int  # unpadded |E|
    num_nodes: int | None  # from cache meta; None on the in-memory path
    stats: FeedStats


class ShardFeeder:
    """Staging allocator + accounting for the per-shard feed.

    Each shard is filled into a **fresh** buffer whose ownership passes to
    the jax runtime at ``device_put``. This is deliberate: PJRT's CPU
    client adopts suitably-aligned host buffers *zero-copy* (alignment-
    dependent, so nondeterministically), which means reusing one staging
    buffer in place would silently corrupt previously-fed shards — the
    regression test ``test_feeder_buffer_is_not_aliased_across_feeds``
    guards exactly that failure mode. Accelerator backends copy to device
    memory and the staging buffer is freed at the next allocation. Either
    way, at most one *transient* staging shard is ever alive beyond the
    device-owned data; ``peak_staging_bytes`` is the feeder-lifetime
    high-water mark of a single staging allocation (shared feeders — e.g.
    one per benchmark sweep — accumulate their max across feeds).
    """

    def __init__(self) -> None:
        self.peak_staging_bytes = 0

    def staging(self, rows: int, stats: "FeedStats | None" = None,
                ) -> np.ndarray:
        """Allocate one staging shard — the single accounting site: the
        feeder-lifetime and per-feed high-water marks are both recorded
        here so they cannot drift apart."""
        buf = np.empty((rows,), np.int32)
        self.peak_staging_bytes = max(self.peak_staging_bytes, buf.nbytes)
        if stats is not None:
            stats.peak_staging_bytes = max(stats.peak_staging_bytes,
                                           buf.nbytes)
        return buf


def shard_layout(num_edges: int, n_dev: int) -> tuple[int, int]:
    """``(rows_per_shard, padded_total)`` for ``num_edges`` over ``n_dev``.

    Matches the historical ``pad_and_shard_edges`` padding exactly
    (``padded = |E| + (−|E| mod n_dev)``), so shard contents — and hence
    every downstream psum'd metric — are bit-identical across paths.
    When ``n_dev ∤ |E|`` the last shard is part padding; when
    ``|E| < n_dev`` trailing shards are *all* padding (``-1`` rows, which
    ``_local_pairs`` already masks out).
    """
    if n_dev <= 0:
        raise ValueError(f"n_dev must be positive, got {n_dev}")
    padded = num_edges + (-num_edges) % n_dev
    return padded // n_dev, padded


def _edge_sharding(mesh) -> tuple[NamedSharding, int]:
    rules = make_rules(mesh, "summarize")
    return NamedSharding(mesh, rules.edge_spec), rules.n_devices


def mesh_process_count(mesh) -> int:
    """Number of OS processes the mesh's devices live in."""
    return len({d.process_index for d in np.asarray(mesh.devices).ravel()})


def _require_single_process(mesh, entry: str) -> None:
    """The single-process feeds stage EVERY shard locally — on a
    process-spanning mesh that is both wrong (``device_put`` onto a
    non-addressable device fails) and, were it patched naively, would
    re-stage the full |E| on every host. Fail loudly and point at the
    multi-host entry point instead of letting jax produce an opaque
    cross-process placement error."""
    n_proc = mesh_process_count(mesh)
    if n_proc > 1:
        raise RuntimeError(
            f"{entry} feeds every shard from one host and cannot run on a "
            f"mesh spanning {n_proc} processes; use "
            f"repro.graphs.feed.shard_edges_from_cache_multihost, which "
            f"stages only the shards addressable by this process "
            f"(DESIGN.md §15)")


def _madvise_dontneed(column) -> None:
    """Drop the resident pages of an mmap'd column (best-effort)."""
    try:
        import mmap as _mmap

        column._mmap.madvise(_mmap.MADV_DONTNEED)  # noqa: SLF001
    except (AttributeError, ValueError, OSError):
        pass


def _feed_column(column, num_edges: int, sharding, padded: int,
                 feeder: ShardFeeder, stats: FeedStats,
                 addressable_only: bool = False) -> jax.Array:
    """Slice one edge column into per-device shards through the feeder.

    ``column`` may be an ``np.memmap`` (cache path — each slice is one
    page-streamed memcpy into staging) or a plain ndarray (memory path).
    With ``addressable_only`` the loop visits only the devices owned by
    *this* process (``addressable_devices_indices_map``), so each host
    stages — and mmap-touches — only its own slice of the columns;
    ``make_array_from_single_device_arrays`` assembles the global array
    from every process's addressable shards (DESIGN.md §15).
    """
    shape = (padded,)
    singles = []
    if addressable_only:
        index_map = sharding.addressable_devices_indices_map(shape)
    else:
        index_map = sharding.devices_indices_map(shape)
    for dev, idx in index_map.items():
        sl = idx[0]
        a = 0 if sl.start is None else int(sl.start)
        b = padded if sl.stop is None else int(sl.stop)
        buf = feeder.staging(b - a, stats)
        n_valid = max(min(num_edges, b) - a, 0)
        if n_valid:
            np.copyto(buf[:n_valid], column[a:a + n_valid],
                      casting="same_kind")
        if n_valid < b - a:
            buf[n_valid:] = -1
        # ownership of ``buf`` passes to the runtime here (PJRT CPU may
        # adopt it zero-copy) — it must never be written again
        singles.append(jax.device_put(buf, dev))
        stats.bytes_copied += buf.nbytes
        del buf
    return jax.make_array_from_single_device_arrays(shape, sharding, singles)


def _feed(src, dst, num_edges: int, mesh, feeder: ShardFeeder | None,
          path: str, num_nodes: int | None,
          addressable_only: bool = False) -> EdgeShards:
    sharding, n_dev = _edge_sharding(mesh)
    shard_rows, padded = shard_layout(num_edges, n_dev)
    feeder = feeder or ShardFeeder()
    stats = FeedStats(num_edges=num_edges, padded_edges=padded,
                      n_devices=n_dev, shard_rows=shard_rows,
                      shard_bytes=shard_rows * 4, path=path,
                      process_count=mesh_process_count(mesh))
    src_g = _feed_column(src, num_edges, sharding, padded, feeder, stats,
                         addressable_only)
    dst_g = _feed_column(dst, num_edges, sharding, padded, feeder, stats,
                         addressable_only)
    stats.local_shards = len(src_g.addressable_shards)
    return EdgeShards(src=src_g, dst=dst_g, num_edges=num_edges,
                      num_nodes=num_nodes, stats=stats)


def shard_edges(src, dst, mesh, *, feeder: ShardFeeder | None = None,
                ) -> EdgeShards:
    """In-memory fallback: shard a canonical edge list already in host RAM.

    Subsumes the old ``pad_and_shard_edges``: same ``-1`` padding, same
    contiguous placement, but built shard-by-shard through the feeder's
    staging buffer instead of a full-length ``np.concatenate`` copy — and
    the result is committed to its final edge sharding, so ``jit`` never
    re-gathers it. Inputs must already be canonical (``src < dst``,
    unique — ``repro.core.types.make_graph`` output or a cache column).
    """
    _require_single_process(mesh, "shard_edges")
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"edge columns must be equal-length 1-D arrays; "
                         f"got {src.shape} vs {dst.shape}")
    return _feed(src, dst, int(src.shape[0]), mesh, feeder, "memory", None)


def shard_edges_from_cache(cache_dir: str, mesh, *,
                           feeder: ShardFeeder | None = None) -> EdgeShards:
    """Feed the binary CSR cache straight onto the mesh, zero-densify.

    Opens the cache's ``src.npy``/``dst.npy`` with ``mmap_mode="r"`` and
    slices them per shard — peak host RSS is one staging shard
    (``FeedStats.shard_bytes``) plus transiently-resident mmap pages,
    which are ``madvise(DONTNEED)``-ed after each column. ``|E|``/``|V|``
    come from ``meta.json``, so nothing is scanned. Raises
    ``FileNotFoundError`` when the cache is missing members or stale
    (``repro.graphs.io.cache_is_fresh``) — callers should re-ingest via
    :func:`repro.graphs.io.load_graph` first.
    """
    _require_single_process(mesh, "shard_edges_from_cache")
    return _feed_cache(cache_dir, mesh, feeder, "cache-mmap",
                       addressable_only=False)


def shard_edges_from_cache_multihost(cache_dir: str, mesh, *,
                                     feeder: ShardFeeder | None = None,
                                     ) -> EdgeShards:
    """Multi-host cache feed: each process stages ONLY its local shards.

    Every participating process calls this with the same ``cache_dir``
    (shared filesystem or an identical local copy) and the same
    process-spanning mesh, after :func:`repro.launch.mesh.
    bootstrap_distributed`. Each process mmaps the cache, slices out just
    the rows its *addressable* devices own, and the global array is
    assembled from the per-process shards — no host ever materializes (or
    even pages in) a full-|E| array, so per-host peak RSS stays at one
    staging shard regardless of process count (DESIGN.md §15; the CI
    ``multihost`` job asserts the RSS budget). Shard layout and padding
    are identical to :func:`shard_edges_from_cache`, so the summary — and
    the launcher JSON — is bit-identical to the single-process run on the
    same global device count. Also valid on a single-process mesh, where
    "addressable" means "all" and it degenerates to the cache feed.
    """
    return _feed_cache(cache_dir, mesh, feeder,
                       "cache-mmap-multihost" if mesh_process_count(mesh) > 1
                       else "cache-mmap",
                       addressable_only=True)


def _feed_cache(cache_dir: str, mesh, feeder: ShardFeeder | None,
                path: str, *, addressable_only: bool) -> EdgeShards:
    if not graph_io.cache_is_fresh(cache_dir):
        raise FileNotFoundError(
            f"{cache_dir!r}: not a complete ssumm cache "
            f"(missing/corrupt members or stale meta.json); "
            f"re-ingest with repro.graphs.io.load_graph")
    with open(os.path.join(cache_dir, "meta.json")) as f:
        meta = json.load(f)
    num_edges = int(meta["num_edges"])
    src_mm = np.load(os.path.join(cache_dir, "src.npy"), mmap_mode="r")
    dst_mm = np.load(os.path.join(cache_dir, "dst.npy"), mmap_mode="r")
    if src_mm.shape[0] != num_edges or dst_mm.shape[0] != num_edges:
        raise ValueError(
            f"{cache_dir!r}: meta.json says |E|={num_edges} but members "
            f"have {src_mm.shape[0]}/{dst_mm.shape[0]} rows")
    out = _feed(src_mm, dst_mm, num_edges, mesh, feeder, path,
                int(meta["num_nodes"]), addressable_only)
    _madvise_dontneed(src_mm)
    _madvise_dontneed(dst_mm)
    return out
