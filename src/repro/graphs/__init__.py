from repro.graphs.synthetic import DATASETS, generate  # noqa: F401
from repro.graphs.io import (  # noqa: F401
    IngestStats,
    LoadedGraph,
    ingest_edge_list,
    load_graph,
    open_csr,
    write_edge_list,
)

# repro.graphs.feed imports jax (via repro.dist); the ingest layer above is
# numpy-only and must stay importable without it (fixture writers, parse
# tooling), so the feed names re-export lazily (PEP 562).
_FEED_NAMES = ("EdgeShards", "FeedStats", "ShardFeeder", "shard_edges",
               "shard_edges_from_cache", "shard_layout")


def __getattr__(name):
    if name in _FEED_NAMES:
        from repro.graphs import feed

        return getattr(feed, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_FEED_NAMES))
