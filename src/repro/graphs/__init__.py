from repro.graphs.synthetic import DATASETS, generate  # noqa: F401
from repro.graphs.io import (  # noqa: F401
    IngestStats,
    LoadedGraph,
    ingest_edge_list,
    load_graph,
    open_csr,
    write_edge_list,
)
