from repro.graphs.synthetic import DATASETS, generate  # noqa: F401
