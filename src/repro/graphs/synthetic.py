"""Synthetic graph generators + the Table-2 stand-in dataset registry.

The container is offline, so the SNAP datasets of Table 2 are replaced by
synthetic graphs with matched |V|, |E| and the degree-heterogeneity family
that each real dataset belongs to (DESIGN.md §9). All quantitative paper
comparisons are therefore *trend-level*. Generators are pure numpy +
deterministic seeds; they emit canonical (src < dst) unique edge lists.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _canonical(src: np.ndarray, dst: np.ndarray, v: int):
    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    keep = lo != hi
    key = lo[keep] * v + hi[keep]
    key = np.unique(key)
    return (key // v).astype(np.int32), (key % v).astype(np.int32)


def erdos_renyi(v: int, e: int, seed: int = 0):
    """G(n, m)-style: sample ~e distinct pairs uniformly."""
    rng = np.random.default_rng(seed)
    m = int(e * 1.15) + 16
    src = rng.integers(0, v, m)
    dst = rng.integers(0, v, m)
    lo, hi = _canonical(src, dst, v)
    return lo[:e], hi[:e]


def barabasi_albert(v: int, m_per_node: int = 4, seed: int = 0):
    """Preferential attachment via the repeated-endpoints trick (O(E))."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    repeated: list[int] = []
    src_l: list[int] = []
    dst_l: list[int] = []
    for u in range(m_per_node, v):
        for t in targets:
            src_l.append(u)
            dst_l.append(t)
        repeated.extend(targets)
        repeated.extend([u] * m_per_node)
        idx = rng.integers(0, len(repeated), m_per_node)
        targets = [repeated[i] for i in idx]
    return _canonical(np.asarray(src_l), np.asarray(dst_l), v)


def rmat(v_log2: int, e: int, seed: int = 0, a=0.57, b=0.19, c=0.19):
    """R-MAT / Graph500-style power-law generator (bit-recursive)."""
    rng = np.random.default_rng(seed)
    n_bits = v_log2
    m = int(e * 1.25) + 16
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for _ in range(n_bits):
        r = rng.random(m)
        src_bit = (r > a + b).astype(np.int64)
        r2 = rng.random(m)
        thr = np.where(src_bit == 0, b / (a + b), c / max(1.0 - a - b, 1e-9))
        dst_bit = (r2 < thr).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    lo, hi = _canonical(src, dst, 1 << n_bits)
    return lo[:e], hi[:e]


def caveman(v: int, clique: int = 16, rewire: float = 0.05, seed: int = 0):
    """Dense communities + random rewiring — the best case for summarization
    (mirrors the community structure of the social/co-purchase datasets)."""
    rng = np.random.default_rng(seed)
    n_cl = v // clique
    src_l, dst_l = [], []
    for g in range(n_cl):
        base = g * clique
        ids = np.arange(base, base + clique)
        iu, ju = np.triu_indices(clique, k=1)
        src_l.append(ids[iu])
        dst_l.append(ids[ju])
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    flip = rng.random(src.shape[0]) < rewire
    dst = np.where(flip, rng.integers(0, v, src.shape[0]), dst)
    return _canonical(src, dst, v)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    short: str
    v: int
    e_target: int
    kind: str  # generator family
    note: str


# Table 2 stand-ins (small/mid rows at full |V|; web-scale rows are dry-run
# only — see configs/ssumm_paper.py for their ShapeDtypeStruct shapes).
DATASETS: dict[str, DatasetSpec] = {
    "ego-facebook": DatasetSpec("ego-facebook", "EF", 4_039, 88_234, "caveman", "social"),
    "caida": DatasetSpec("caida", "CA", 26_475, 106_762, "rmat", "internet"),
    "email-enron": DatasetSpec("email-enron", "EE", 36_692, 183_831, "rmat", "email"),
    "amazon0302": DatasetSpec("amazon0302", "A3", 262_111, 899_792, "ba", "co-purchase"),
    "dblp": DatasetSpec("dblp", "DB", 317_080, 1_049_866, "caveman", "collaboration"),
    "amazon0601": DatasetSpec("amazon0601", "A6", 403_394, 2_443_408, "ba", "co-purchase"),
    "skitter": DatasetSpec("skitter", "SK", 1_696_415, 11_095_298, "rmat", "internet"),
    "livejournal": DatasetSpec("livejournal", "LJ", 3_997_962, 34_681_189, "rmat", "social"),
    "web-uk-02": DatasetSpec("web-uk-02", "W2", 18_483_186, 261_787_258, "rmat", "hyperlinks (dry-run only)"),
    "web-uk-05": DatasetSpec("web-uk-05", "W5", 39_454_463, 783_027_125, "rmat", "hyperlinks (dry-run only)"),
}


def generate(name: str, seed: int = 0, scale: float = 1.0):
    """Materialize a registry dataset (optionally scaled down by ``scale``).

    Returns ``(src, dst, num_nodes)``.
    """
    spec = DATASETS[name]
    v = max(int(spec.v * scale), 64)
    e = max(int(spec.e_target * scale), 128)
    if spec.kind == "caveman":
        src, dst = caveman(v, clique=max(int(2 * e / v), 3), seed=seed)
    elif spec.kind == "ba":
        src, dst = barabasi_albert(v, m_per_node=max(e // v, 1), seed=seed)
    else:
        bits = int(np.ceil(np.log2(v)))
        src, dst = rmat(bits, e, seed=seed)
        v = 1 << bits
    return src, dst, v
