"""Distribution substrate shared by the SSumM summarizer and the LM stack.

Three concerns, one vocabulary:

  * :mod:`repro.dist.sharding` — logical-axis → mesh-axis rule tables
    (``make_rules(mesh, mode)``) consumed by the lowering, dry-run, train,
    serve, and distributed-summarize paths, plus the supernode ownership
    hash the edge-sharded step routes with;
  * :mod:`repro.dist.compress` — int8 / top-k payload codecs with
    error-feedback buffers for the cross-pod gradient boundary;
  * :mod:`repro.dist.microbatch` — gradient accumulation that matches the
    full-batch gradient.

:mod:`repro.dist.compat` isolates the jax-version differences (shard_map
location, mesh axis types) so the rest of the tree imports one stable API.
"""

from repro.dist.compat import make_mesh, shard_map
from repro.dist.compress import (
    CompressConfig,
    compressed_allreduce,
    decode_int8,
    encode_int8,
    encode_topk,
    init_error_buffers,
    payload_bytes,
)
from repro.dist.microbatch import microbatch_grads
from repro.dist.sharding import MeshRules, make_rules, owner_hash_np

__all__ = [
    "CompressConfig",
    "MeshRules",
    "compressed_allreduce",
    "decode_int8",
    "encode_int8",
    "encode_topk",
    "init_error_buffers",
    "make_mesh",
    "make_rules",
    "microbatch_grads",
    "owner_hash_np",
    "payload_bytes",
    "shard_map",
]
