"""Microbatched gradient accumulation.

``microbatch_grads`` splits the global batch into ``accum`` equal
microbatches along the leading axis, runs value-and-grad per microbatch
under ``lax.scan`` (one microbatch of activations live at a time — the
memory point of accumulation), and averages losses/aux/grads. Gradients
are accumulated in float32 regardless of the parameter dtype and cast back
at the end, so ``accum=k`` reproduces the ``accum=1`` gradient up to
rounding of the final cast.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def microbatch_grads(loss_fn, params, batch, accum: int = 1):
    """Accumulated gradients of ``loss_fn`` over ``accum`` microbatches.

    ``loss_fn(params, batch) -> (loss, aux)`` (aux: dict of scalar
    metrics). Returns ``(loss, aux, grads)`` — the means over microbatches;
    with equal microbatch sizes these equal the full-batch quantities.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if accum <= 1:
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, grads

    def split(x):
        b = x.shape[0]
        if b % accum != 0:
            raise ValueError(
                f"leading batch dim {b} not divisible by accum={accum}"
            )
        return x.reshape((accum, b // accum) + x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(g_acc, mb):
        (loss, aux), grads = grad_fn(params, mb)
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / accum, g_acc, grads
        )
        return g_acc, (loss, aux)

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    g_sum, (losses, auxes) = jax.lax.scan(body, zeros, micro)
    grads = jax.tree.map(lambda g, p: g.astype(p.dtype), g_sum, params)
    loss = jnp.mean(losses)
    aux = jax.tree.map(lambda x: jnp.mean(x, axis=0), auxes)
    return loss, aux, grads
