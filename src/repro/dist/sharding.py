"""Sharding rule tables: logical axis names → mesh axes, per launch mode.

Every path that places data on a mesh — the lowering/dry-run cells, the
train and serve drivers, and the distributed SSumM step — resolves its
shardings through one :class:`MeshRules` table built by
:func:`make_rules(mesh, mode)`. Logical names are the vocabulary the model
``axes()`` trees and ``rules.constrain`` call sites already speak:

    batch seq kvseq embed act_embed attn_embed heads kv_heads ff vocab
    experts                                  (LM stack)
    edges                                    (edge-sharded summarization)

Modes:
  * ``train``     — DP over (pod, data), TP over model, FSDP: the ``embed``
    parameter dimension is additionally sharded over the DP axes;
  * ``serve``     — TP over model plus sequence parallelism (``seq``) and
    flash-decoding cache splits (``kvseq``) on the model axis;
  * ``summarize`` — edges sharded over *every* mesh axis, partition state
    replicated (DESIGN.md §7), plus the supernode ownership hash used by
    the pair-routing all-to-all;
  * ``eval``      — offline batch inference: the batch dimension is
    sharded over *every* mesh axis (throughput, not latency, is the
    objective) and parameters stay replicated — no TP collectives in the
    step, so independent shards stream through with zero cross-device
    traffic.

Rule application is shape-aware: a mesh axis is dropped for a given array
dimension when it does not divide the dimension or is already taken by an
earlier dimension of the same spec — smoke-sized configs lower on any mesh
without per-call special-casing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Tensor-parallel parameter/activation dimensions: sharded over "model" in
# every LM mode.
_TP_AXES = ("ff", "heads", "kv_heads", "vocab", "experts", "attn_embed")

# Logical names every mode's table defines (the full vocabulary above).
_LOGICAL = _TP_AXES + (
    "batch", "seq", "kvseq", "embed", "act_embed", "edges",
)

# Knuth multiplicative constant for the re-drawable supernode ownership
# hash — defined once here so the distributed step and any tooling that
# predicts record placement agree on the routing.
OWNER_HASH_MULT = 2654435761

MODES = ("train", "serve", "summarize", "eval")


def owner_hash_np(ids, salt: int, n_devices: int) -> "np.ndarray":
    """Numpy twin of :meth:`MeshRules.owner` — same uint32 math, host side.

    The partitioned query tier builds its halo tables on the host before
    any device data exists; it must agree bit-for-bit with the device-side
    routing hash (tests/test_sharding_rules.py pins the equivalence).
    """
    ids = np.asarray(ids)
    with np.errstate(over="ignore"):
        x = (ids.astype(np.uint32) * np.uint32(OWNER_HASH_MULT)) ^ np.uint32(
            salt
        )
    x = (x >> np.uint32(16)) ^ x
    return (x % np.uint32(max(1, int(n_devices)))).astype(np.int32)


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """A resolved logical-axis → mesh-axis table bound to one mesh."""

    mesh: Any
    mode: str
    table: Mapping[str, Any]  # logical name -> mesh axis | tuple | None

    # ------------------------------------------------------------ topology
    @property
    def axis_names(self) -> tuple:
        return tuple(self.mesh.axis_names)

    @property
    def n_devices(self) -> int:
        return int(self.mesh.size)

    @property
    def dp_axes(self) -> tuple:
        return _dp_axes(self.mesh)

    # ------------------------------------------------------- spec assembly
    def mesh_axes(self, logical) -> tuple:
        """The (possibly multi-axis) mesh assignment of one logical name."""
        if logical is None:
            return ()
        if logical not in self.table:  # typos must not silently replicate
            raise KeyError(
                f"unknown logical axis {logical!r}; known: {sorted(self.table)}"
            )
        assign = self.table[logical]
        if assign is None:
            return ()
        return (assign,) if isinstance(assign, str) else tuple(assign)

    def spec(self, logical_axes, shape=None) -> P:
        """PartitionSpec for a tuple of logical names.

        ``shape`` (when given) enables the divisibility guard; an axis
        already consumed by an earlier dimension is never reused.
        """
        used: set = set()
        entries = []
        for i, name in enumerate(logical_axes):
            kept = []
            prod = 1
            dim = None if shape is None else shape[i]
            for ax in self.mesh_axes(name):
                if ax in used or ax not in self.mesh.shape:
                    continue
                size = int(self.mesh.shape[ax])
                if dim is not None and dim % (prod * size) != 0:
                    continue
                kept.append(ax)
                used.add(ax)
                prod *= size
            if not kept:
                entries.append(None)
            elif len(kept) == 1:
                entries.append(kept[0])
            else:
                entries.append(tuple(kept))
        return P(*entries)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes):
        """``with_sharding_constraint`` under this table (shape-guarded)."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(logical_axes, x.shape)
        )

    # ----------------------------------------- distributed summarization
    @property
    def edge_spec(self) -> P:
        """Edge shards: dimension 0 split over every mesh axis."""
        return self.spec(("edges",))

    @property
    def replicated(self) -> P:
        return P()

    def owner(self, ids, salt):
        """Device owning supernode ``ids`` for this iteration's ``salt``.

        Cheap re-drawable multiplicative hash (mod device count): re-drawn
        every iteration so all supernode pairs are eventually co-owned —
        the distributed analogue of the paper's disjoint candidate sets.
        """
        x = (ids.astype(jnp.uint32) * jnp.uint32(OWNER_HASH_MULT)) ^ (
            salt.astype(jnp.uint32)
        )
        x = (x >> 16) ^ x
        return (x % jnp.uint32(self.n_devices)).astype(jnp.int32)


def _mode_table(mesh, mode: str) -> dict:
    dp = _dp_axes(mesh) or None
    tp = _tp_axis(mesh)
    table: dict = {name: None for name in _LOGICAL}
    if mode == "summarize":
        table["edges"] = tuple(mesh.axis_names)
        table["batch"] = dp
        return table
    if mode == "eval":
        # offline batch: every device is a data-parallel lane; weights
        # replicated, so the only sharded dimension is the batch.
        table["batch"] = tuple(mesh.axis_names)
        return table
    table.update({name: tp for name in _TP_AXES})
    table["batch"] = dp
    if mode == "train":
        # FSDP: parameters additionally sharded over the DP axes along the
        # embed dimension (gathered on the fly by GSPMD).
        table["embed"] = dp
    elif mode == "serve":
        # sequence parallelism for prefill activations, flash-decoding
        # splits for the KV cache — both on the TP axis.
        table["seq"] = tp
        table["kvseq"] = tp
    return table


def _validate_override(mesh, key: str, val) -> None:
    """An override must name real axes of *this* mesh (or None).

    Without this check a typo'd axis (``seq=modell``) silently replicates
    the dimension — ``MeshRules.spec`` drops unknown axes by design for
    shape-guarding, which is exactly wrong for user-supplied overrides.
    """
    if val is None:
        return
    if isinstance(val, str):
        axes = (val,)
    elif isinstance(val, (tuple, list)):
        axes = tuple(val)
    else:
        raise ValueError(
            f"override {key!r}={val!r}: expected a mesh axis name, a "
            f"tuple of names, or None; got {type(val).__name__}"
        )
    mesh_axes = tuple(mesh.axis_names)
    for ax in axes:
        if not isinstance(ax, str) or ax not in mesh_axes:
            raise ValueError(
                f"override {key!r}={val!r}: {ax!r} is not an axis of this "
                f"mesh; mesh axes: {mesh_axes}"
            )
    if len(set(axes)) != len(axes):
        raise ValueError(
            f"override {key!r}={val!r} names a mesh axis more than once"
        )


def make_rules(mesh, mode: str, *, overrides: Mapping[str, Any] | None = None,
               ) -> MeshRules:
    """Build the rule table for ``mesh`` in ``mode``.

    ``overrides`` remaps individual logical names (value: mesh axis name,
    tuple of names, or None to replicate) — the dry-run's perf-iteration
    knobs (``seq=model``, ``batch=data+model``, …) come through here. Keys
    must be known logical names and values must name axes of ``mesh``;
    both are validated eagerly with a KeyError/ValueError rather than
    silently replicating the dimension.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    table = _mode_table(mesh, mode)
    for key, val in (overrides or {}).items():
        if key not in table:
            raise KeyError(
                f"unknown logical axis {key!r}; known: {sorted(table)}"
            )
        _validate_override(mesh, key, val)
        table[key] = val
    return MeshRules(mesh=mesh, mode=mode, table=table)
