"""jax version compatibility for the sharding APIs this repo leans on.

The tree targets jax ≥ 0.4.37 but uses three APIs that moved or were
renamed in later releases:

  * ``shard_map`` — ``jax.experimental.shard_map.shard_map(check_rep=...)``
    in 0.4.x, promoted to ``jax.shard_map(check_vma=...)`` later;
  * ``jax.sharding.AxisType`` — does not exist in 0.4.x (all mesh axes are
    implicitly auto-partitioned there);
  * ``jax.make_mesh(axis_types=...)`` — the kwarg appears together with
    ``AxisType``.

Every call site goes through this module so the rest of the tree is
version-agnostic.
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.5-era API
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # 0.4.x: axes are auto-typed, nothing to request
    AxisType = None

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KWARG = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with the replication-check kwarg of either era."""
    kwargs = {} if check_vma is None else {_CHECK_KWARG: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API wants them."""
    if AxisType is not None:
        try:
            return jax.make_mesh(
                shape, axis_names, axis_types=(AxisType.Auto,) * len(axis_names)
            )
        except TypeError:  # AxisType importable but kwarg not accepted
            pass
    return jax.make_mesh(shape, axis_names)
