"""Gradient/payload codecs for the cross-pod collective boundary.

Two wire formats over arbitrary pytrees, both jit-safe and shape-stable
(fixed output shapes regardless of values, so one compilation serves every
step):

  * **int8** — per-leaf absmax quantization: 1 byte/element + one f32
    scale per leaf; round-to-nearest keeps the reconstruction within half
    a quantization step.
  * **top-k** — magnitude sparsification with error feedback: each leaf
    sends its ``ceil(ratio·n)`` largest-magnitude entries (as a dense
    zero-masked tensor locally; value+index pairs on the wire) and folds
    the unsent remainder into a persistent residual buffer so the signal
    is conserved across steps (Stich et al.-style EF-SGD).

``payload_bytes`` prices a tree under a :class:`CompressConfig` — the
roofline and collective-breakdown tooling use it to convert tree sizes
into wire bytes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    """Payload compression choice at the collective boundary."""

    kind: str = "none"  # none | int8 | topk
    topk_ratio: float = 0.05

    def __post_init__(self):
        if self.kind not in ("none", "int8", "topk"):
            raise ValueError(f"unknown compression kind {self.kind!r}")


# ---------------------------------------------------------------------------
# int8 absmax quantization
# ---------------------------------------------------------------------------


def _int8_scale(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    return jnp.where(scale > 0.0, scale, 1.0)


def encode_int8(tree):
    """Quantize every leaf to int8. Returns ``(q_tree, scale_tree)``."""
    scales = jax.tree.map(_int8_scale, tree)
    q = jax.tree.map(
        lambda x, s: jnp.clip(
            jnp.round(x.astype(jnp.float32) / s), -127.0, 127.0
        ).astype(jnp.int8),
        tree,
        scales,
    )
    return q, scales


def decode_int8(q_tree, scale_tree):
    """Dequantize an :func:`encode_int8` pair back to float32 leaves."""
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree
    )


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def init_error_buffers(tree):
    """Zero residual buffers (f32, one per leaf) for :func:`encode_topk`."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _topk_leaf(g, err, ratio: float):
    acc = g.astype(jnp.float32) + err
    flat = acc.ravel()
    k = max(int(np.ceil(ratio * flat.size)), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    sent = jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(g.shape)
    sent = sent.astype(g.dtype)
    # residual against the values as actually sent (post-cast), so the
    # conservation invariant holds for low-precision gradients too
    residual = acc - sent.astype(jnp.float32)
    return sent, residual


def encode_topk(tree, err, ratio: float):
    """Send the top ``ratio`` fraction of each leaf; keep the rest as error.

    ``err`` may be None on the first step (treated as zeros). Returns the
    dense zero-masked ``sent`` tree (same dtypes as ``tree``) and the new
    residual tree; ``sent + residual`` equals the accumulated signal
    exactly, so nothing is ever dropped — only delayed.
    """
    if err is None:
        err = init_error_buffers(tree)
    leaves_g, treedef = jax.tree.flatten(tree)
    leaves_e = jax.tree.leaves(err)
    pairs = [_topk_leaf(g, e, ratio) for g, e in zip(leaves_g, leaves_e)]
    sent = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    residual = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return sent, residual


# ---------------------------------------------------------------------------
# wire-size accounting
# ---------------------------------------------------------------------------


def payload_bytes(tree, config: CompressConfig) -> float:
    """Bytes on the wire for one all-reduce payload of ``tree``."""
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        if config.kind == "none":
            total += n * np.dtype(leaf.dtype).itemsize
        elif config.kind == "int8":
            total += n + 4.0  # 1 B/element + one f32 scale per leaf
        else:  # topk: (value in the leaf's dtype, int32 index) per entry
            k = max(int(np.ceil(config.topk_ratio * n)), 1)
            total += k * (np.dtype(leaf.dtype).itemsize + 4.0)
    return total
