"""Gradient/payload codecs for the cross-pod collective boundary.

Two wire formats over arbitrary pytrees, both jit-safe and shape-stable
(fixed output shapes regardless of values, so one compilation serves every
step):

  * **int8** — per-leaf absmax quantization: 1 byte/element + one f32
    scale per leaf; round-to-nearest keeps the reconstruction within half
    a quantization step.
  * **top-k** — magnitude sparsification with error feedback: each leaf
    sends its ``ceil(ratio·n)`` largest-magnitude entries (as a dense
    zero-masked tensor locally; value+index pairs on the wire) and folds
    the unsent remainder into a persistent residual buffer so the signal
    is conserved across steps (Stich et al.-style EF-SGD).

``payload_bytes`` prices a tree under a :class:`CompressConfig` — the
roofline and collective-breakdown tooling use it to convert tree sizes
into wire bytes. :func:`compressed_allreduce` is the codecs' *collective*
form: called inside a ``shard_map`` body it moves exactly the priced
payload over the mesh axes (int8 ints + scales, top-k value/index pairs)
and returns a psum'd byte counter measured from the actual wire-array
shapes — so ``launch/train.py`` can assert its accounting against what
was really exchanged, including across OS processes (DESIGN.md §15).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    """Payload compression choice at the collective boundary."""

    kind: str = "none"  # none | int8 | topk
    topk_ratio: float = 0.05

    def __post_init__(self):
        if self.kind not in ("none", "int8", "topk"):
            raise ValueError(f"unknown compression kind {self.kind!r}")


# ---------------------------------------------------------------------------
# int8 absmax quantization
# ---------------------------------------------------------------------------


def _int8_scale(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    return jnp.where(scale > 0.0, scale, 1.0)


def encode_int8(tree):
    """Quantize every leaf to int8. Returns ``(q_tree, scale_tree)``."""
    scales = jax.tree.map(_int8_scale, tree)
    q = jax.tree.map(
        lambda x, s: jnp.clip(
            jnp.round(x.astype(jnp.float32) / s), -127.0, 127.0
        ).astype(jnp.int8),
        tree,
        scales,
    )
    return q, scales


def decode_int8(q_tree, scale_tree):
    """Dequantize an :func:`encode_int8` pair back to float32 leaves."""
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree
    )


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def init_error_buffers(tree):
    """Zero residual buffers (f32, one per leaf) for :func:`encode_topk`."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _topk_leaf(g, err, ratio: float):
    acc = g.astype(jnp.float32) + err
    flat = acc.ravel()
    k = max(int(np.ceil(ratio * flat.size)), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    sent = jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(g.shape)
    sent = sent.astype(g.dtype)
    # residual against the values as actually sent (post-cast), so the
    # conservation invariant holds for low-precision gradients too
    residual = acc - sent.astype(jnp.float32)
    return sent, residual


def encode_topk(tree, err, ratio: float):
    """Send the top ``ratio`` fraction of each leaf; keep the rest as error.

    ``err`` may be None on the first step (treated as zeros). Returns the
    dense zero-masked ``sent`` tree (same dtypes as ``tree``) and the new
    residual tree; ``sent + residual`` equals the accumulated signal
    exactly, so nothing is ever dropped — only delayed.
    """
    if err is None:
        err = init_error_buffers(tree)
    leaves_g, treedef = jax.tree.flatten(tree)
    leaves_e = jax.tree.leaves(err)
    pairs = [_topk_leaf(g, e, ratio) for g, e in zip(leaves_g, leaves_e)]
    sent = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    residual = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return sent, residual


# ---------------------------------------------------------------------------
# shard_map'd compressed all-reduce
# ---------------------------------------------------------------------------


def _leaf_k(n: int, ratio: float) -> int:
    return max(int(np.ceil(ratio * n)), 1)


def _topk_wire_leaf(g, err, ratio: float, axis_names):
    """One leaf of the top-k all-reduce: gather value/index pairs, scatter-
    add; the residual never leaves the device (error feedback is local
    state — asserted by ``tests/wire_check.py``)."""
    acc = g.astype(jnp.float32) + err
    flat = acc.ravel()
    k = _leaf_k(flat.size, ratio)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx].astype(g.dtype)  # wire dtype = leaf dtype (pricing)
    # top_k indices are distinct, so .add subtracts exactly the sent values
    residual = flat.at[idx].add(-vals.astype(jnp.float32)).reshape(g.shape)
    vg = jax.lax.all_gather(vals, axis_names)  # [n_dev, k]
    ig = jax.lax.all_gather(idx, axis_names)
    summed = (jnp.zeros((flat.size,), jnp.float32)
              .at[ig.ravel()].add(vg.ravel().astype(jnp.float32)))
    return summed.reshape(g.shape).astype(g.dtype), residual


def compressed_allreduce(tree, err, config: CompressConfig, axis_names):
    """Sum ``tree`` over the mesh axes through the configured wire format.

    Call **inside** a ``shard_map`` body; every device contributes its own
    ``tree`` (same shapes everywhere) and receives the sum of all
    contributions. Returns ``(summed, new_err, wire_bytes)``:

    * ``kind="none"`` — a plain ``psum``; exact.
    * ``kind="int8"`` — each device quantizes its contribution
      (:func:`encode_int8`), the int8 payloads + f32 scales are
      all-gathered, and each device decodes-and-sums locally, so only
      1 B/element (+4 B/leaf) ever crosses the wire.
    * ``kind="topk"`` — each device gathers only its ``k`` largest
      accumulated entries as (value, index) pairs; the unsent remainder
      stays in the **process-local** ``new_err`` residual (pass it back
      next call; ``None`` means zeros).

    ``wire_bytes`` is a psum'd f32 scalar of the bytes every device put on
    the wire, computed from the actual wire-array shapes — it equals
    ``axis_size × payload_bytes(tree, config)`` by construction, which is
    exactly the assertion ``launch/train.py`` makes.
    """
    per_device = 0.0
    if config.kind == "none":
        summed = jax.lax.psum(tree, axis_names)
        new_err = err
        for g in jax.tree.leaves(tree):
            per_device += g.size * np.dtype(g.dtype).itemsize
    elif config.kind == "int8":
        q, scales = encode_int8(tree)
        new_err = err

        def _sum_leaf(qi, si):
            qg = jax.lax.all_gather(qi, axis_names)      # [n_dev, ...] int8
            sg = jax.lax.all_gather(si, axis_names)      # [n_dev] f32
            sg = sg.reshape((sg.shape[0],) + (1,) * qi.ndim)
            return jnp.sum(qg.astype(jnp.float32) * sg, axis=0)

        summed = jax.tree.map(_sum_leaf, q, scales)
        summed = jax.tree.map(lambda s, g: s.astype(g.dtype), summed, tree)
        for qi in jax.tree.leaves(q):
            per_device += qi.size * 1 + 4.0  # int8 payload + one f32 scale
    else:  # topk
        if err is None:
            err = init_error_buffers(tree)
        leaves_g, treedef = jax.tree.flatten(tree)
        leaves_e = jax.tree.leaves(err)
        pairs = [_topk_wire_leaf(g, e, config.topk_ratio, axis_names)
                 for g, e in zip(leaves_g, leaves_e)]
        summed = jax.tree.unflatten(treedef, [p[0] for p in pairs])
        new_err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
        for g in leaves_g:
            k = _leaf_k(g.size, config.topk_ratio)
            per_device += k * (np.dtype(g.dtype).itemsize + 4.0)
    wire_bytes = jax.lax.psum(jnp.float32(per_device), axis_names)
    return summed, new_err, wire_bytes


# ---------------------------------------------------------------------------
# wire-size accounting
# ---------------------------------------------------------------------------


def payload_bytes(tree, config: CompressConfig) -> float:
    """Bytes on the wire for one all-reduce payload of ``tree``."""
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        if config.kind == "none":
            total += n * np.dtype(leaf.dtype).itemsize
        elif config.kind == "int8":
            total += n + 4.0  # 1 B/element + one f32 scale per leaf
        else:  # topk: (value in the leaf's dtype, int32 index) per entry
            k = max(int(np.ceil(config.topk_ratio * n)), 1)
            total += k * (np.dtype(leaf.dtype).itemsize + 4.0)
    return total
