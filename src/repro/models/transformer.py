"""Dense / MoE decoder-only transformer (gemma, deepseek, qwen, danube,
granite, moonshot, paligemma backbone).

Layers are a *python loop* (not ``lax.scan``): HLO then carries every
layer's ops so ``cost_analysis`` FLOPs/bytes are exact (DESIGN.md §8 — scan
bodies are counted once by XLA). Each block is wrapped in ``jax.checkpoint``
for training so the dry-run memory analysis reflects the remat policy that
would be used on hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.common import (
    Px,
    apply_mlp,
    apply_norm,
    embed_init,
    init_mlp,
    init_norm,
)


def init_block(key, cfg, dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(k1, cfg.d_model, cfg.norm),
        "attn": attn.init_attention(k2, cfg, dtype=dtype),
        "ln2": init_norm(k3, cfg.d_model, cfg.norm),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(k4, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k4, cfg.d_model, cfg.d_ff, dtype)
    return p


def apply_block(p, x, cfg, *, rules=None, window=None):
    """Train/prefill block: pre-norm attn + (MoE|MLP), residual."""
    aux = {}
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    x = x + attn.attention(p["attn"], h, cfg, window=window, rules=rules)
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_lib.apply_moe(p["moe"], h, cfg, rules)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act, rules)
    x = x + y
    if rules is not None:
        x = rules.constrain(x, "batch", "seq", "act_embed")
    return x, aux


def apply_block_decode(p, x, cfg, cache, pos, *, rules=None, window=None):
    """One-token decode block. cache = {"k": [B,T,K,hd], "v": ...}."""
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    a, new_k, new_v = attn.attention_decode(
        p["attn"], h, cfg, cache["k"], cache["v"], pos, window=window, rules=rules
    )
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_lib.apply_moe(p["moe"], h, cfg, rules)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act, rules)
    x = x + y
    return x, {"k": new_k, "v": new_v}


def init_lm(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.n_layers + 3)
    p = {
        "embed": Px(embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
                    ("vocab", "embed")),
        "ln_f": init_norm(keys[1], cfg.d_model, cfg.norm),
    }
    for i in range(cfg.n_layers):
        p[f"layer_{i}"] = init_block(keys[2 + i], cfg, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = Px(
            embed_init(keys[-1], (cfg.vocab, cfg.d_model), dtype),
            ("vocab", "embed"),
        )
    return p


def _window(cfg, i: int):
    return cfg.swa_window  # uniform SWA (danube); None = full attention


def embed_tokens(params, tokens, cfg):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" or cfg.name.startswith("gemma"):
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(h.dtype)
    return h


def unembed(params, h, cfg, rules=None):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
    if rules is not None:
        logits = rules.constrain(logits, "batch", "seq", "vocab")
    return logits


def forward(params, tokens, cfg, *, rules=None, remat: bool = True,
            prefix_emb=None, last_only: bool = False):
    """Token logits for train/prefill. ``prefix_emb`` (VLM/audio): embeddings
    prepended before the token embeddings (stub modality frontends)."""
    h = embed_tokens(params, tokens, cfg)
    if prefix_emb is not None:
        h = jnp.concatenate([prefix_emb.astype(h.dtype), h], axis=1)
    if rules is not None:
        h = rules.constrain(h, "batch", "seq", "act_embed")
    aux_tot = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        # close over everything non-array so jax.checkpoint sees arrays only
        blk = functools.partial(
            apply_block, cfg=cfg, rules=rules, window=_window(cfg, i)
        )
        if remat:
            # remat policy (§Perf): True/"full" recomputes everything;
            # "dots" saves matmul outputs (no-batch-dim dots) — less
            # backward recompute traffic for more live memory
            policy = None
            if remat == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            blk = jax.checkpoint(blk, prevent_cse=False, policy=policy)
        h, aux = blk(params[f"layer_{i}"], h)
        if "moe_aux" in aux:
            aux_tot = aux_tot + aux["moe_aux"]
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    if last_only:  # prefill: only the last position's logits are served
        h = h[:, -1:]
    logits = unembed(params, h, cfg, rules)
    return logits, {"moe_aux": aux_tot / max(cfg.n_layers, 1)}


def decode_step(params, token, cache, pos, cfg, *, rules=None):
    """token: [B] int32; cache: {"layer_i": {"k","v"}}; pos: scalar int32."""
    h = embed_tokens(params, token[:, None], cfg)
    new_cache = {}
    for i in range(cfg.n_layers):
        h, c = apply_block_decode(
            params[f"layer_{i}"], h, cfg, cache[f"layer_{i}"], pos,
            rules=rules, window=_window(cfg, i),
        )
        new_cache[f"layer_{i}"] = c
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    logits = unembed(params, h, cfg, rules)
    return logits[:, 0], new_cache


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    c = {}
    for i in range(cfg.n_layers):
        shape = (batch, seq_len, cfg.n_kv_heads, cfg.hd)
        c[f"layer_{i}"] = {
            "k": jnp.zeros(shape, dtype),
            "v": jnp.zeros(shape, dtype),
        }
    return c


def cache_axes(cfg):
    return {
        f"layer_{i}": {
            "k": ("batch", "kvseq", "kv_heads", None),
            "v": ("batch", "kvseq", "kv_heads", None),
        }
        for i in range(cfg.n_layers)
    }
