"""Whisper-large-v3 backbone: 32-layer encoder + 32-layer decoder, d=1280.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings [B, enc_len=1500, d]. Pre-LN LayerNorm blocks,
non-gated GELU MLPs, sinusoidal positions (learned-pos is an initialization
detail, not a shape/architecture difference — noted in DESIGN.md §6).

Decode shapes: decoder self-attention KV cache of the assigned seq_len plus
a cross-attention KV cache projected once from the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (
    Px,
    apply_norm,
    dense_init,
    embed_init,
    init_norm,
    sinusoidal_pos,
)


def init_plain_mlp(key, d, f, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "wi": Px(dense_init(k1, (d, f), 0, dtype), ("embed", "ff")),
        "wo": Px(dense_init(k2, (f, d), 0, dtype), ("ff", "embed")),
    }


def apply_plain_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def init_enc_block(key, cfg, dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": init_norm(k1, cfg.d_model, "layernorm"),
        "attn": attn.init_attention(k2, cfg, dtype=dtype, bias=True),
        "ln2": init_norm(k3, cfg.d_model, "layernorm"),
        "mlp": init_plain_mlp(k4, cfg.d_model, cfg.d_ff, dtype),
    }


def init_dec_block(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    return {
        "ln1": init_norm(ks[0], cfg.d_model, "layernorm"),
        "self_attn": attn.init_attention(ks[1], cfg, dtype=dtype, bias=True),
        "ln2": init_norm(ks[2], cfg.d_model, "layernorm"),
        "cross_attn": attn.init_attention(ks[3], cfg, dtype=dtype, bias=True),
        "ln3": init_norm(ks[4], cfg.d_model, "layernorm"),
        "mlp": init_plain_mlp(ks[5], cfg.d_model, cfg.d_ff, dtype),
    }


def init_whisper(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 4)
    p = {
        "embed": Px(embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
                    ("vocab", "embed")),
        "ln_enc": init_norm(keys[1], cfg.d_model, "layernorm"),
        "ln_dec": init_norm(keys[2], cfg.d_model, "layernorm"),
    }
    for i in range(cfg.enc_layers):
        p[f"enc_{i}"] = init_enc_block(keys[3 + i], cfg, dtype)
    for i in range(cfg.n_layers):
        p[f"dec_{i}"] = init_dec_block(keys[3 + cfg.enc_layers + i], cfg, dtype)
    return p


def encode(params, frames, cfg, *, rules=None):
    """frames: [B, enc_len, d] (stub frontend output)."""
    h = frames + sinusoidal_pos(frames.shape[1], cfg.d_model).astype(frames.dtype)
    if rules is not None:
        h = rules.constrain(h, "batch", "seq", "act_embed")
    for i in range(cfg.enc_layers):
        p = params[f"enc_{i}"]
        a = apply_norm(p["ln1"], h, "layernorm")
        h = h + attn.attention(p["attn"], a, cfg, causal=False, rules=rules,
                               use_rope=False)
        m = apply_norm(p["ln2"], h, "layernorm")
        h = h + apply_plain_mlp(p["mlp"], m)
    return apply_norm(params["ln_enc"], h, "layernorm")


def decode_train(params, tokens, enc_out, cfg, *, rules=None,
                 last_only: bool = False):
    """Teacher-forced decoder over full token sequence (train/prefill)."""
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h + sinusoidal_pos(s, cfg.d_model).astype(h.dtype)
    if rules is not None:
        h = rules.constrain(h, "batch", "seq", "act_embed")
    for i in range(cfg.n_layers):
        p = params[f"dec_{i}"]
        a = apply_norm(p["ln1"], h, "layernorm")
        h = h + attn.attention(p["self_attn"], a, cfg, causal=True, rules=rules,
                               use_rope=False)
        a = apply_norm(p["ln2"], h, "layernorm")
        ck, cv = attn.project_cross_kv(p["cross_attn"], enc_out)
        h = h + attn.cross_attention(p["cross_attn"], a, ck, cv, rules=rules)
        m = apply_norm(p["ln3"], h, "layernorm")
        h = h + apply_plain_mlp(p["mlp"], m)
    h = apply_norm(params["ln_dec"], h, "layernorm")
    if last_only:
        h = h[:, -1:]
    return jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)


def decode_step(params, token, cache, pos, cfg, *, rules=None):
    """One-token decode. cache: per-layer self k/v + precomputed cross k/v."""
    b = token.shape[0]
    h = jnp.take(params["embed"], token[:, None], axis=0)
    pos_emb = sinusoidal_pos(cache["dec_0"]["k"].shape[1], cfg.d_model)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # per-slot ok
    h = h + pos_emb[posv][:, None].astype(h.dtype)
    new_cache = dict(cache)
    for i in range(cfg.n_layers):
        p = params[f"dec_{i}"]
        c = cache[f"dec_{i}"]
        a = apply_norm(p["ln1"], h, "layernorm")
        o, nk, nv = attn.attention_decode(
            p["self_attn"], a, cfg, c["k"], c["v"], pos, rules=rules,
            use_rope=False,
        )
        h = h + o
        a = apply_norm(p["ln2"], h, "layernorm")
        h = h + attn.cross_attention(
            p["cross_attn"], a, c["xk"], c["xv"], rules=rules
        )
        m = apply_norm(p["ln3"], h, "layernorm")
        h = h + apply_plain_mlp(p["mlp"], m)
        new_cache[f"dec_{i}"] = {"k": nk, "v": nv, "xk": c["xk"], "xv": c["xv"]}
    h = apply_norm(params["ln_dec"], h, "layernorm")
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    return logits[:, 0], new_cache


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    c = {}
    for i in range(cfg.n_layers):
        c[f"dec_{i}"] = {
            "k": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dtype),
            "xk": jnp.zeros((batch, cfg.enc_len, cfg.n_kv_heads, cfg.hd), dtype),
            "xv": jnp.zeros((batch, cfg.enc_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    return c


def cache_axes(cfg):
    return {
        f"dec_{i}": {
            "k": ("batch", "kvseq", "kv_heads", None),
            "v": ("batch", "kvseq", "kv_heads", None),
            "xk": ("batch", None, "kv_heads", None),
            "xv": ("batch", None, "kv_heads", None),
        }
        for i in range(cfg.n_layers)
    }
