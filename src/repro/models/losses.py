"""Training losses: causal LM cross-entropy (f32, z-loss) + MoE aux."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_lm_loss(logits, tokens, *, z_loss: float = 1e-4, moe_aux=None,
                   moe_aux_weight: float = 1e-2, prefix_len: int = 0):
    """Next-token prediction: logits[:, t] predicts tokens[:, t+1].

    ``prefix_len``: number of leading positions (image/audio prefix) whose
    predictions are not scored.
    """
    lg = logits[:, prefix_len:-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    total = nll
    if z_loss:
        total = total + z_loss * jnp.mean(lse**2)
    if moe_aux is not None:
        total = total + moe_aux_weight * moe_aux
    return total, {"nll": nll, "ppl_proxy": jnp.exp(jnp.minimum(nll, 20.0))}


def seq2seq_loss(logits, tokens, **kw):
    return causal_lm_loss(logits, tokens, **kw)
