"""GQA/MQA/SWA attention with train, prefill, and cached-decode paths.

Layouts:
    q        [B, S, H, hd]          k/v  [B, T, K, hd]
    scores   [B, K, g, S, T]        (g = H // K query groups)

Decode sharding (serve rules): the KV cache sequence axis is mapped to
"model" — GSPMD partitions the contraction over T and inserts the partial
softmax combine (flash-decoding) as a psum pair; see DESIGN.md §7.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init, rope

NEG_INF = -1e30


def init_attention(key, cfg, d_model=None, dtype=jnp.bfloat16, bias=None):
    d = d_model or cfg.d_model
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    use_bias = cfg.qkv_bias if bias is None else bias
    ks = jax.random.split(key, 4)
    p = {
        "wq": Px(dense_init(ks[0], (d, h, hd), 0, dtype), ("attn_embed", "heads", None)),
        "wk": Px(dense_init(ks[1], (d, k, hd), 0, dtype), ("attn_embed", "kv_heads", None)),
        "wv": Px(dense_init(ks[2], (d, k, hd), 0, dtype), ("attn_embed", "kv_heads", None)),
        "wo": Px(dense_init(ks[3], (h, hd, d), None, dtype), ("heads", None, "attn_embed")),
    }
    if use_bias:
        p["bq"] = Px(jnp.zeros((h, hd), dtype), ("heads", None))
        p["bk"] = Px(jnp.zeros((k, hd), dtype), ("kv_heads", None))
        p["bv"] = Px(jnp.zeros((k, hd), dtype), ("kv_heads", None))
    return p


def _project_qkv(p, x, rules=None):
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _mask(pos_q, pos_k, causal: bool, window, valid_len=None):
    """[S, T] additive mask. window = sliding-window size (None = full)."""
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= (pos_q[:, None] - pos_k[None, :]) < window
    if valid_len is not None:
        m &= pos_k[None, :] < valid_len
    return jnp.where(m, 0.0, NEG_INF)


def mha(q, k, v, mask, rules=None):
    """Grouped attention core; softmax in f32."""
    b, s, h, hd = q.shape
    kk = k.shape[2]
    g = h // kk
    q = q.reshape(b, s, kk, g, hd)
    scores = jnp.einsum("bskgx,btkx->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = scores + mask  # broadcast [S, T]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkx->bskgx", w.astype(v.dtype), v)
    return out.reshape(b, s, h * hd)


def attention(
    p,
    x,
    cfg,
    *,
    positions=None,
    causal: bool = True,
    window=None,
    rules=None,
    use_rope: bool = True,
):
    """Full-sequence attention (train / prefill) — blockwise online-softmax
    (see models/flash.py; full scores are never materialized)."""
    from repro.models.flash import blockwise_attention

    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, rules)
    pos = positions if positions is not None else jnp.arange(s)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    if rules is not None:
        q = rules.constrain(q, "batch", "seq", "heads", None)
        k = rules.constrain(k, "batch", "seq", "kv_heads", None)
        v = rules.constrain(v, "batch", "seq", "kv_heads", None)
    out = blockwise_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(b, s, -1)
    return jnp.einsum("bsy,yd->bsd", out, p["wo"].reshape(-1, p["wo"].shape[-1]))


def attention_decode(
    p,
    x,
    cfg,
    cache_k,
    cache_v,
    pos,  # int32 scalar OR int32[B]: per-sequence index of the new token
    *,
    window=None,
    rules=None,
    use_rope: bool = True,
):
    """One-token decode against a pre-filled KV cache.

    cache_k/v: [B, T, K, hd]. ``pos`` may be a scalar (lockstep decode — the
    dry-run serving shape) or a per-sequence vector (continuous batching:
    each slot advances independently). Returns (out [B, 1, d], new_k, new_v).
    """
    b, t, kk, hd = cache_k.shape
    q, k_new, v_new = _project_qkv(p, x, rules)  # S = 1
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # [B]
    if use_rope:
        q = rope(q, posv[:, None], cfg.rope_theta)
        k_new = rope(k_new, posv[:, None], cfg.rope_theta)
    idx = jnp.arange(b)
    cache_k = cache_k.at[idx, posv].set(k_new[:, 0])
    cache_v = cache_v.at[idx, posv].set(v_new[:, 0])
    if rules is not None:
        cache_k = rules.constrain(cache_k, "batch", "kvseq", "kv_heads", None)
        cache_v = rules.constrain(cache_v, "batch", "kvseq", "kv_heads", None)
    pos_k = jnp.arange(t)
    # per-sequence causal (+ window) mask: [B, 1, 1, 1, T] broadcast over
    # the [B, K, g, S, T] score layout
    m = pos_k[None, :] <= posv[:, None]
    if window is not None:
        m &= (posv[:, None] - pos_k[None, :]) < window
    mask = jnp.where(m, 0.0, NEG_INF)[:, None, None, None, :]
    out = mha(q, cache_k, cache_v, mask, rules)
    out = jnp.einsum("bsy,yd->bsd", out, p["wo"].reshape(-1, p["wo"].shape[-1]))
    return out, cache_k, cache_v


def cross_attention(p, x, kv_cache_k, kv_cache_v, rules=None):
    """Encoder-decoder cross attention (whisper): cache is the projected
    encoder output; no masking, no RoPE."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    t = kv_cache_k.shape[1]
    mask = jnp.zeros((x.shape[1], t), jnp.float32)
    out = mha(q, kv_cache_k, kv_cache_v, mask, rules)
    return jnp.einsum("bsy,yd->bsd", out, p["wo"].reshape(-1, p["wo"].shape[-1]))


def project_cross_kv(p, enc_out):
    k = jnp.einsum("btd,dkx->btkx", enc_out, p["wk"])
    v = jnp.einsum("btd,dkx->btkx", enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v
