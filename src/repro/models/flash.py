"""Blockwise (flash-style) attention in pure JAX for long sequences.

Materializing [B, H, S, T] scores at 32k–500k sequence lengths is impossible
(43 GB+/device), so train/prefill attention runs the online-softmax blocked
algorithm: an outer ``lax.scan`` over query blocks and an inner ``lax.scan``
over KV blocks, carrying (running max, denominator, accumulator). Peak live
memory is one [B, heads, q_block, kv_block] score tile.

Roofline note (EXPERIMENTS.md §Roofline): XLA's ``cost_analysis`` counts a
scan body exactly once, so HLO FLOPs undercount attention by the factor
``nq·nkv``. The dry-run extractor adds the analytic correction
``F_attn·(1 − 1/(nq·nkv))`` — formulas in launch/costs.py; everything
outside these scans is loop-free and exactly counted.

Causal block skipping is intentionally NOT performed (all blocks computed,
masked) so the analytic correction stays exact; the §Perf hillclimb measures
the causal-skip variant separately.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fit_block(n: int, want: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``want`` (sequences like whisper's
    1500 frames don't divide the default power-of-two blocks)."""
    if n <= want:
        return n
    if n % want == 0:
        return want
    return max(d for d in range(1, want + 1) if n % d == 0)


def blockwise_attention(
    q,  # [B, S, H, hd]
    k,  # [B, T, K, hd]
    v,  # [B, T, K, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 256,
    kv_block: int = 1024,
    q_offset: int = 0,  # position of q[0] (prefill continuation)
) -> jax.Array:
    b, s, h, hd = q.shape
    t, kk = k.shape[1], k.shape[2]
    g = h // kk
    qb = _fit_block(s, q_block)
    kb = _fit_block(t, kv_block)
    nq, nk = s // qb, t // kb

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    q_r = q.reshape(b, nq, qb, kk, g, hd).transpose(1, 0, 2, 3, 4, 5)
    k_r = k.reshape(b, nk, kb, kk, hd).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(b, nk, kb, kk, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi):
        q_blk, iq = qi  # [B, qb, K, g, hd], scalar block index
        pos_q = q_offset + iq * qb + jnp.arange(qb)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk, v_blk, jk = kj
            pos_k = jk * kb + jnp.arange(kb)
            s_blk = (
                jnp.einsum("bqkgx,btkx->bkgqt", q_blk, k_blk).astype(jnp.float32)
                * scale
            )
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= pos_q[:, None] >= pos_k[None, :]
            if window is not None:
                mask &= (pos_q[:, None] - pos_k[None, :]) < window
            s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkx->bkgqx", p, v_blk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kk, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kk, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kk, g, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_r, v_r, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B, K, g, qb, hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qb, h, hd)
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (q_r, jnp.arange(nq)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attention_flops(
    b: int, s: int, t: int, h: int, hd: int, *, mode: str, remat: bool
) -> tuple[float, float]:
    """(true, hlo-counted) attention matmul FLOPs for the roofline correction.

    fwd = 4·B·H·S·T·hd (QKᵀ + PV). train: bwd = 2·fwd, remat adds 1 fwd.
    Counted-by-HLO = true / (nq·nkv) with the default block sizes.
    """
    fwd = 4.0 * b * h * s * t * hd
    if mode == "train":
        mult = 4.0 if remat else 3.0
    else:
        mult = 1.0
    true = fwd * mult
    qb = min(256, s)
    kb = min(1024, t)
    counted = true / ((s // qb) * (t // kb))
    return true, counted
