"""Shared model building blocks: params-with-axes, norms, RoPE, MLPs.

Parameters are plain nested dicts of ``jax.Array``. A parallel *axes* tree
(same structure, leaves = tuples of logical axis names) drives sharding; both
trees are built together by the ``init_*`` functions through ``Px``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Px:
    """A parameter leaf paired with its logical sharding axes."""

    value: jax.Array
    axes: tuple

    @property
    def shape(self):
        return self.value.shape


# registered as a pytree (axes = aux data) so init functions can run under
# jax.eval_shape — the dry-run derives parameter ShapeDtypeStructs + logical
# axes without allocating anything.
jax.tree_util.register_pytree_node(
    Px,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Px(children[0], axes),
)


def split_tree(tree):
    """Split a Px-leafed tree into (values, axes) trees."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=lambda x: isinstance(x, Px))
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=lambda x: isinstance(x, Px))
    return values, axes


def param_count(values) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(values)))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> jax.Array:
    fan_in = shape[in_axis] if in_axis is not None else int(np.prod(shape[:-1]))
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (params in f32, math in f32, cast back)
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(key, d, kind: str = "rmsnorm"):
    del key
    if kind == "rmsnorm":
        return {"scale": Px(jnp.zeros((d,), jnp.float32), (None,))}
    return {
        "scale": Px(jnp.ones((d,), jnp.float32), (None,)),
        "bias": Px(jnp.zeros((d,), jnp.float32), (None,)),
    }


def apply_norm(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10_000.0):
    """Apply RoPE. x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.arange(half, dtype=jnp.float32) / half
    inv = theta**-freq  # [half]
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d: int):
    pos = np.arange(seq_len)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d, f, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": Px(dense_init(k1, (d, f), 0, dtype), ("embed", "ff")),
        "wg": Px(dense_init(k2, (d, f), 0, dtype), ("embed", "ff")),
        "wo": Px(dense_init(k3, (f, d), 0, dtype), ("ff", "embed")),
    }


def apply_mlp(p, x, act: str = "silu", rules=None):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    if act == "gelu":
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return jnp.einsum("...f,fd->...d", h, p["wo"])
