"""Mixture-of-Experts FFN: top-k router + sort-based dropless-ish dispatch.

Design (DESIGN.md §7): the GShard one-hot dispatch tensor [N, E, C] is
infeasible at the assigned shapes, so dispatch is a *sort*:

    1. router: logits [N, E] → top-k (expert, weight) records (N·k records)
    2. sort records by expert id; rank-in-segment gives per-expert slots
    3. scatter tokens into capacity buckets  x_e [E, C, d]
    4. two batched einsums with the expert weights (E is the EP axis —
       sharded over "model"; GSPMD turns scatter/gather across the token
       and expert shardings into the dispatch collectives)
    5. scatter-add weighted outputs back to token order.

Tokens beyond an expert's capacity C = ceil(k·N·cf/E) are dropped (standard
capacity-factor semantics; counted in aux stats). Router runs in f32; an
auxiliary load-balancing loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import shard_map
from repro.models.common import Px, dense_init
from repro.utils import boundaries_from_keys, rank_in_segment


def init_moe(key, cfg, dtype=jnp.bfloat16, ep: int = 16):
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.experts_padded(ep)
    ks = jax.random.split(key, 4)
    return {
        "router": Px(dense_init(ks[0], (d, e), 0, jnp.float32), ("embed", "experts")),
        "wi": Px(dense_init(ks[1], (e, d, f), 1, dtype), ("experts", "embed", "ff")),
        "wg": Px(dense_init(ks[2], (e, d, f), 1, dtype), ("experts", "embed", "ff")),
        "wo": Px(dense_init(ks[3], (e, f, d), 1, dtype), ("experts", "ff", "embed")),
    }


def _router_probs(router_w, xt, e_real: int):
    """Masked router softmax in f32 (padding experts get -inf logits)."""
    e_pad = router_w.shape[-1]
    logits = xt.astype(jnp.float32) @ router_w
    if e_pad > e_real:
        logits = jnp.where(jnp.arange(e_pad)[None, :] >= e_real, -1e30, logits)
    return jax.nn.softmax(logits, axis=-1)


def _load_balance_aux(probs, e_real: int):
    """Switch-style load-balance loss from the (masked) router probs."""
    e_pad = probs.shape[-1]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, axis=-1), e_pad, dtype=jnp.float32),
        axis=0,
    )
    frac_probs = jnp.mean(probs, axis=0)
    return e_real * jnp.sum(frac_tokens * frac_probs)


def apply_moe(p, x, cfg, rules=None, capacity_factor: float | None = None):
    """Dispatch selector: GSPMD baseline vs explicit-a2a EP (§Perf iter. 1).

    The a2a path requires token shards that *vary* along the EP axis
    (seq divisible by the "model" axis) — single-token decode keeps the
    GSPMD path, where the dispatch buffers are small anyway."""
    impl = cfg.moe.impl if cfg.moe is not None else "gspmd"
    if (impl == "a2a" and rules is not None and rules.mesh is not None
            and "model" in rules.mesh.axis_names
            and x.shape[1] % rules.mesh.shape["model"] == 0):
        return apply_moe_a2a(p, x, cfg, rules, capacity_factor)
    return apply_moe_gspmd(p, x, cfg, rules, capacity_factor)


def apply_moe_gspmd(p, x, cfg, rules=None, capacity_factor: float | None = None):
    """x: [B, S, d] → ([B, S, d], aux dict)."""
    b, s, d = x.shape
    n = b * s
    e_real = cfg.moe.num_experts
    e_pad = p["router"].shape[-1]
    k = cfg.moe.top_k
    cf = capacity_factor or cfg.moe.capacity_factor
    if s == 1:
        # single-token decode: dropless (buffers are tiny; capacity drops
        # would make decode diverge from the training forward)
        cap = n * k
    else:
        cap = max(int(k * n * cf / e_real), 1)

    xt = x.reshape(n, d)
    probs = _router_probs(p["router"], xt, e_real)
    top_w, top_e = jax.lax.top_k(probs, k)  # [N, k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    # permutation computed on integer keys only (argsort is gradient-free);
    # values are then *gathered*, keeping the combine path differentiable.
    rec_e = top_e.reshape(-1).astype(jnp.int32)  # [N·k]
    rec_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    rec_w = top_w.reshape(-1).astype(jnp.float32)
    perm = jnp.argsort(rec_e * (n + 1) + rec_t)  # stable (expert, token) order
    e_s = rec_e[perm]
    t_s = rec_t[perm]
    w_s = rec_w[perm]
    slot = rank_in_segment(boundaries_from_keys(e_s))
    ok = slot < cap
    flat = jnp.where(ok, e_s * cap + slot, e_pad * cap)  # OOB → dropped
    x_e = jnp.zeros((e_pad * cap + 1, d), x.dtype)
    x_e = x_e.at[flat].set(xt[t_s], mode="drop")[:-1].reshape(e_pad, cap, d)
    if rules is not None:
        x_e = rules.constrain(x_e, "experts", None, None)

    # ---- expert computation (E = EP axis) ------------------------------
    h = jnp.einsum("ecd,edf->ecf", x_e, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", x_e, p["wg"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    if rules is not None:
        y_e = rules.constrain(y_e, "experts", None, None)

    # ---- combine back to token order ------------------------------------
    # clamped gather, no sentinel row: dropped records read a live row and
    # are masked to an exact 0 (select, not multiply — robust to inf/nan
    # in expert outputs). A concat-then-gather sentinel here is miscompiled
    # by the XLA SPMD partitioner on meshes with a data axis — every output
    # gets multiplied by the data-axis size.
    y_flat = y_e.reshape(e_pad * cap, d)
    src = jnp.where(ok, flat, 0)
    gathered = jnp.where(ok[:, None], y_flat[src].astype(jnp.float32), 0.0)
    contrib = gathered * jnp.where(ok, w_s, 0.0)[:, None]
    y = jnp.zeros((n, d), jnp.float32).at[t_s].add(contrib)

    aux_loss = _load_balance_aux(probs, e_real)
    dropped = jnp.sum(~ok) / jnp.maximum(n * k, 1)
    return y.reshape(b, s, d).astype(x.dtype), {
        "moe_aux": aux_loss,
        "moe_drop_frac": dropped,
    }


# ---------------------------------------------------------------------------
# §Perf iteration 1: explicit expert-parallel dispatch under shard_map
# ---------------------------------------------------------------------------
#
# Hypothesis (EXPERIMENTS.md §Perf): under pure GSPMD the sort-based
# scatter/gather between token-sharded activations and expert-sharded
# buffers has data-dependent indices, so the partitioner falls back to
# all-gather/all-reduce of the *full* dispatch buffers — ~10 TB/device of
# collective traffic per moonshot prefill step. The classic fix is the
# MoE all-to-all: route each token shard directly to the EP rank that owns
# its expert. Payload per device per layer becomes k·n_local·cf·d bf16
# each way (~126 MB for moonshot prefill) — a ~3 orders-of-magnitude cut.
#
# Layout: tokens enter sharded [B/dp, S/tp, d]; experts are sharded over
# "model" (e_local = E/tp per rank). Each rank:
#   1. routes its n_local tokens (router weights are replicated),
#   2. packs per-EP-group buckets [tp, cap_r, d] (capacity-dropped, counted),
#   3. all_to_all over "model" → receives the tokens destined to its experts,
#   4. local sort-based dispatch over e_local experts (second capacity),
#   5. all_to_all back and weighted scatter-add into token order.
# Every step is differentiable (argsort keys are gradient-free; data moves
# via gather/scatter-add and a2a, both with well-defined transposes).


def _dispatch_to_buckets(vals, keys, n_buckets: int, cap: int, fill=0.0):
    """Scatter ``vals`` rows into [n_buckets, cap, ...] by ``keys`` (sorted
    stable order); returns (buckets, sort_order, flat_slot_per_row, ok_mask)."""
    order = jnp.argsort(keys, stable=True)
    k_s = keys[order]
    slot = rank_in_segment(boundaries_from_keys(k_s))
    ok = (slot < cap) & (k_s < n_buckets)
    flat = jnp.where(ok, k_s * cap + slot, n_buckets * cap)
    out_shape = (n_buckets * cap + 1,) + vals.shape[1:]
    buckets = jnp.full(out_shape, fill, vals.dtype)
    buckets = buckets.at[flat].set(vals[order], mode="drop")[:-1]
    return buckets.reshape((n_buckets, cap) + vals.shape[1:]), order, flat, ok


def apply_moe_a2a(p, x, cfg, rules, capacity_factor: float | None = None):
    """Explicit-collective EP MoE (see header). Same numerics contract as
    the GSPMD path (capacity drops differ only in which tokens overflow)."""
    mesh = rules.mesh
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    assert x.shape[1] % mesh.shape["model"] == 0, "a2a needs seq % EP == 0"
    seq_ax = "model"
    ep = mesh.shape["model"]
    e_pad = p["router"].shape[-1]
    e_real = cfg.moe.num_experts
    k = cfg.moe.top_k
    cf = capacity_factor or cfg.moe.capacity_factor
    assert e_pad % ep == 0, (e_pad, ep)
    e_local = e_pad // ep

    from jax.sharding import PartitionSpec as P

    x_spec = P(dp_axes if dp_axes else None, seq_ax, None)
    p_specs = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wg": P("model", None, None),
        "wo": P("model", None, None),
    }

    def body(params, xl):
        b_l, s_l, d = xl.shape
        n_l = b_l * s_l
        cap_r = max(int(k * n_l * cf / ep), 1)       # per-destination-rank
        cap_e = max(int(2 * ep * cap_r / e_local), 1)  # local per-expert

        xt = xl.reshape(n_l, d)
        probs = _router_probs(params["router"], xt, e_real)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

        rec_e = top_e.reshape(-1).astype(jnp.int32)           # [N·k]
        rec_t = jnp.repeat(jnp.arange(n_l, dtype=jnp.int32), k)
        rec_w = top_w.reshape(-1).astype(jnp.float32)
        grp = rec_e // e_local                                 # EP rank

        # ---- pack per-rank buckets and route -------------------------------
        payload = xt[rec_t]                                    # [N·k, d]
        buckets, order, flat, ok = _dispatch_to_buckets(payload, grp, ep, cap_r)
        eid_rows = jnp.where(ok, (rec_e % e_local)[order], -1).astype(jnp.int32)
        eid_buckets = jnp.full((ep * cap_r + 1,), -1, jnp.int32)
        eid_buckets = eid_buckets.at[flat].set(eid_rows, mode="drop")[:-1]
        recv = jax.lax.all_to_all(buckets, "model", 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(
            eid_buckets.reshape(ep, cap_r), "model", 0, 0, tiled=False)
        recv = recv.reshape(ep * cap_r, d)
        recv_eid = recv_eid.reshape(ep * cap_r)

        # ---- local expert compute (second, local dispatch) ------------------
        key2 = jnp.where(recv_eid >= 0, recv_eid, e_local)
        x_e, order2, flat2, ok2 = _dispatch_to_buckets(recv, key2, e_local,
                                                       cap_e)
        wi, wg, wo = params["wi"], params["wg"], params["wo"]
        h = jnp.einsum("ecd,edf->ecf", x_e, wi)
        g = jnp.einsum("ecd,edf->ecf", x_e, wg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_e.dtype) * h
        y_e = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e_local * cap_e, d)

        # undo local dispatch: back to received-slot order
        y_pad = jnp.concatenate([y_e, jnp.zeros((1, d), y_e.dtype)])
        y_recv = jnp.zeros((ep * cap_r, d), y_e.dtype)
        y_recv = y_recv.at[order2].set(
            y_pad[jnp.minimum(flat2, e_local * cap_e)]
        )

        # ---- route back and combine -----------------------------------------
        back = jax.lax.all_to_all(y_recv.reshape(ep, cap_r, d), "model", 0, 0,
                                  tiled=False).reshape(ep * cap_r, d)
        back_pad = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)])
        per_rec = back_pad[jnp.minimum(flat, ep * cap_r)]      # sorted order
        contrib = per_rec.astype(jnp.float32) * jnp.where(
            ok, rec_w[order], 0.0)[:, None]
        y = jnp.zeros((n_l, d), jnp.float32).at[rec_t[order]].add(contrib)

        # ---- drop accounting (metric only — no gradient) --------------------
        # token shards vary over dp axes AND the EP ("model") axis
        all_axes = dp_axes + ("model",)
        drop1 = jnp.sum(~ok) / jnp.maximum(n_l * k, 1)
        # ok2 is False for both overflowed AND padding slots — only count
        # slots that carried a real token (recv_eid ≥ 0)
        n_valid2 = jnp.sum(recv_eid >= 0)
        drop2 = (n_valid2 - jnp.sum(ok2)) / jnp.maximum(n_l * k, 1)
        dropped = jax.lax.stop_gradient(
            jax.lax.pmean(drop1 + drop2, all_axes))
        return y.reshape(b_l, s_l, d).astype(xl.dtype), dropped

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()),
    )
    y, dropped = sharded(
        {k_: p[k_] for k_ in ("router", "wi", "wg", "wo")}, x
    )

    # Load-balance aux loss, recomputed outside the shard_map from the
    # (replicated) router: per-token quantities mean-reduce identically to
    # the per-shard pmean, the router matmul is cheap, and the shard_map
    # keeps y as its only differentiable output — this jax's shard_map
    # transpose cannot take symbolic-zero cotangents for extra outputs.
    xt = x.reshape(-1, x.shape[-1])
    aux = _load_balance_aux(_router_probs(p["router"], xt, e_real), e_real)
    return y, {"moe_aux": aux, "moe_drop_frac": dropped}
