"""Mamba2 (SSD — state-space duality) blocks for zamba2.

Chunked algorithm (one ``lax.scan`` over chunks, carrying the inter-chunk
state): within a chunk the quadratic "attention-like" form is computed with
batched einsums; across chunks only the [B, H, N, P] state flows. Peak live
memory is one [B, Q, Q, H] tile (Q = cfg.ssm_chunk).

Roofline note: the chunk scan body is counted once by ``cost_analysis``; the
analytic correction (launch/costs.py) adds the remaining (nc−1)/nc of the
SSD FLOPs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, apply_norm, dense_init, init_norm

CONV_W = 4


def dims(cfg, d_model=None):
    d = d_model or cfg.d_model
    di = cfg.ssm_expand * d
    p = cfg.ssm_head_dim
    h = di // p
    n = cfg.ssm_state
    return d, di, h, p, n


def init_mamba2(key, cfg, dtype=jnp.bfloat16):
    d, di, h, p, n = dims(cfg)
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * n  # conv over (x, B, C) as in mamba2
    return {
        "ln": init_norm(ks[0], d, cfg.norm),
        "in_proj": Px(
            dense_init(ks[1], (d, 2 * di + 2 * n + h), 0, dtype),
            ("embed", "ff"),
        ),
        "conv_w": Px(
            (jax.random.normal(ks[2], (CONV_W, conv_ch), jnp.float32) * 0.1).astype(dtype),
            (None, "ff"),
        ),
        "conv_b": Px(jnp.zeros((conv_ch,), dtype), ("ff",)),
        "a_log": Px(jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)), (None,)),
        "dt_bias": Px(jnp.zeros((h,), jnp.float32), (None,)),
        "d_skip": Px(jnp.ones((h,), jnp.float32), (None,)),
        "out_norm": init_norm(ks[3], di, cfg.norm),
        "out_proj": Px(dense_init(ks[4], (di, d), 0, dtype), ("ff", "embed")),
    }


def _split(p, cfg, u):
    """in_proj output → (z, x, B, C, dt_raw)."""
    _, di, h, _, n = dims(cfg)
    z, x, b_, c_, dt = jnp.split(u, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, b_, c_, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv over [B, S, C] with kernel [W, C]."""
    pad = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(CONV_W)
    )
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def ssd_forward(p, x_in, cfg, *, rules=None, chunk=None):
    """Full-sequence SSD. x_in: [B, S, d] → [B, S, d]."""
    d, di, h, hp, n = dims(cfg)
    b, s, _ = x_in.shape
    q = min(chunk or cfg.ssm_chunk, s)
    nc = s // q
    assert s % q == 0

    res = x_in
    u = apply_norm(p["ln"], x_in, cfg.norm, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xc, b_, c_, dt_raw = _split(p, cfg, u)
    xbc = _causal_conv(jnp.concatenate([xc, b_, c_], -1), p["conv_w"], p["conv_b"])
    xc, b_, c_ = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H]
    loga = dt * a[None, None, :]  # [B,S,H]  (≤ 0)
    xh = xc.reshape(b, s, h, hp).astype(jnp.float32)
    xdt = xh * dt[..., None]  # discretized input
    bf = b_.astype(jnp.float32)  # [B,S,N] (ngroups=1, shared across heads)
    cf = c_.astype(jnp.float32)

    # chunked layout
    la = loga.reshape(b, nc, q, h)
    lcs = jnp.cumsum(la, axis=2)  # within-chunk cumulative log decay
    ltot = lcs[:, :, -1, :]  # [B,nc,H]
    xq = xdt.reshape(b, nc, q, h, hp)
    bq = bf.reshape(b, nc, q, n)
    cq = cf.reshape(b, nc, q, n)

    xs = (
        jnp.moveaxis(xq, 1, 0),
        jnp.moveaxis(bq, 1, 0),
        jnp.moveaxis(cq, 1, 0),
        jnp.moveaxis(lcs, 1, 0),
        jnp.moveaxis(ltot, 1, 0),
    )

    def chunk_step(hstate, xs_c):
        xck, bck, cck, lck, ltotk = xs_c  # [B,q,...]
        # intra-chunk quadratic form
        cb = jnp.einsum("bin,bjn->bij", cck, bck)  # [B,q,q]
        dec = jnp.exp(
            jnp.clip(lck[:, :, None, :] - lck[:, None, :, :], -60.0, 0.0)
        )  # [B,q,q,H]
        iota = jnp.arange(q)
        causal = (iota[:, None] >= iota[None, :]).astype(jnp.float32)
        w = cb[..., None] * dec * causal[None, :, :, None]  # [B,q,q,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xck)
        # contribution of the carried inter-chunk state
        dec_i = jnp.exp(jnp.clip(lck, -60.0, 0.0))  # [B,q,H]
        y_carry = jnp.einsum("bin,bhnp->bihp", cck, hstate) * dec_i[..., None]
        # new chunk state
        dec_j = jnp.exp(jnp.clip(ltotk[:, None, :] - lck, -60.0, 0.0))  # [B,q,H]
        s_c = jnp.einsum("bjn,bjh,bjhp->bhnp", bck, dec_j, xck)
        h_new = jnp.exp(jnp.clip(ltotk, -60.0, 0.0))[..., None, None] * hstate + s_c
        return h_new, y_intra + y_carry

    h0 = jnp.zeros((b, h, n, hp), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, xs)  # [nc, B, q, H, P]
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hp)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = apply_norm(p["out_norm"], y, cfg.norm, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if rules is not None:
        out = rules.constrain(out, "batch", "seq", "act_embed")
    return res + out


def ssd_decode(p, x_in, cfg, state, *, rules=None):
    """One-token decode. state = {"h": [B,H,N,P] f32, "conv": [B,W-1,C]}."""
    d, di, h, hp, n = dims(cfg)
    b = x_in.shape[0]
    res = x_in
    u = apply_norm(p["ln"], x_in, cfg.norm, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xc, b_, c_, dt_raw = _split(p, cfg, u)
    xbc_new = jnp.concatenate([xc, b_, c_], -1)  # [B,1,C]
    conv_buf = jnp.concatenate([state["conv"], xbc_new], axis=1)  # [B,W,C]
    out = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(out.astype(jnp.float32)).astype(x_in.dtype)[:, None, :]
    xc, b_, c_ = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, :])  # [B,H]
    xh = xc[:, 0].reshape(b, h, hp).astype(jnp.float32)
    bf = b_[:, 0].astype(jnp.float32)  # [B,N]
    cf = c_[:, 0].astype(jnp.float32)
    hs = state["h"] * da[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", bf, dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cf, hs) + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = apply_norm(p["out_norm"], y, cfg.norm, cfg.norm_eps)
    out_t = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return res + out_t, {"h": hs, "conv": conv_buf[:, 1:, :]}


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16):
    d, di, h, hp, n = dims(cfg)
    return {
        "h": jnp.zeros((batch, h, n, hp), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, di + 2 * n), dtype),
    }


def ssm_state_axes(cfg):
    return {"h": ("batch", None, None, None), "conv": ("batch", None, None)}
