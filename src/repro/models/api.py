"""Unified model API: one entry point for all 10 assigned architectures.

``build_model(cfg)`` returns a :class:`Model` with pure functions:

    init(rng)                         → params (+ .axes logical-axis tree)
    forward(params, batch, rules)     → (logits, aux)          train/prefill
    loss(params, batch, rules)        → (scalar, metrics)
    train_step(params, opt, batch, rules, run) → (params, opt, metrics)
    serve_step(params, batch, rules)  → (logits[B,V], new_cache)  decode
    init_cache(batch, seq_len)        → decode cache pytree
    cache_axes()                      → logical axes for the cache

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
input of the step that the shape's kind lowers (the dry-run contract).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.models import transformer, whisper, xlstm, zamba2
from repro.models.common import Px, dense_init, split_tree
from repro.models.losses import causal_lm_loss
from repro.optim import adamw_update, cosine_schedule


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init_px: Callable  # rng -> Px tree
    forward: Callable  # (params, batch, rules, remat) -> (logits, aux)
    decode: Callable  # (params, batch, rules) -> (logits, new_cache)
    init_cache: Callable
    cache_axes: Callable
    prefix_len: int = 0

    def init(self, rng):
        values, _ = split_tree(self.init_px(rng))
        return values

    def axes(self, rng=None):
        tree = jax.eval_shape(self.init_px, rng or jax.random.PRNGKey(0))
        _, axes = split_tree(tree)
        return axes

    # ---- steps ----------------------------------------------------------
    def loss(self, params, batch, rules=None, remat: bool = True):
        logits, aux = self.forward(params, batch, rules, remat)
        return causal_lm_loss(
            logits,
            batch["tokens"],
            moe_aux=aux.get("moe_aux"),
            prefix_len=self.prefix_len,
        )

    def train_step(self, params, opt_state, batch, rules=None, run=None,
                   remat: bool = True):
        from repro.configs.base import RunConfig

        run = run or RunConfig()

        def loss_fn(p):
            return self.loss(p, batch, rules, remat)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        # schedule is evaluated at the step being *taken* (step+1): warmup
        # starts at lr>0 so the very first update moves the params.
        lr = cosine_schedule(
            opt_state.step + 1, base_lr=run.lr, warmup=run.warmup_steps,
            total=run.total_steps, min_ratio=run.lr_min_ratio,
        )
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip,
        )
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    def serve_step(self, params, batch, rules=None):
        return self.decode(params, batch, rules)

    def prefill_step(self, params, batch, rules=None):
        """Prefill: full-sequence forward, last-position logits only."""
        logits, _ = self.forward(params, batch, rules, False, last_only=True)
        return logits[:, -1]


# ---------------------------------------------------------------------------
# family assemblies
# ---------------------------------------------------------------------------


def _dense_family(cfg: ModelConfig) -> Model:
    def fwd(params, batch, rules=None, remat=True, last_only=False):
        return transformer.forward(params, batch["tokens"], cfg, rules=rules,
                                   remat=remat, last_only=last_only)

    def dec(params, batch, rules=None):
        return transformer.decode_step(
            params, batch["token"], batch["cache"], batch["pos"], cfg, rules=rules
        )

    return Model(
        cfg=cfg,
        init_px=lambda rng: transformer.init_lm(rng, cfg, _dtype(cfg)),
        forward=fwd,
        decode=dec,
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s, _dtype(cfg)),
        cache_axes=lambda: transformer.cache_axes(cfg),
    )


def _vlm_family(cfg: ModelConfig) -> Model:
    def init_px(rng):
        k1, k2 = jax.random.split(rng)
        p = transformer.init_lm(k1, cfg, _dtype(cfg))
        p["img_proj"] = Px(
            dense_init(k2, (cfg.img_dim, cfg.d_model), 0, _dtype(cfg)),
            (None, "embed"),
        )
        return p

    def fwd(params, batch, rules=None, remat=True, last_only=False):
        prefix = jnp.einsum(
            "bti,id->btd", batch["img_emb"].astype(_dtype(cfg)), params["img_proj"]
        )
        return transformer.forward(params, batch["tokens"], cfg, rules=rules,
                                   remat=remat, prefix_emb=prefix,
                                   last_only=last_only)

    def dec(params, batch, rules=None):
        return transformer.decode_step(
            params, batch["token"], batch["cache"], batch["pos"], cfg, rules=rules
        )

    return Model(
        cfg=cfg,
        init_px=init_px,
        forward=fwd,
        decode=dec,
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s, _dtype(cfg)),
        cache_axes=lambda: transformer.cache_axes(cfg),
        prefix_len=cfg.img_tokens,
    )


def _xlstm_family(cfg: ModelConfig) -> Model:
    def fwd(params, batch, rules=None, remat=True, last_only=False):
        return xlstm.xlstm_forward(params, batch["tokens"], cfg, rules=rules,
                                   remat=remat, last_only=last_only)

    def dec(params, batch, rules=None):
        return xlstm.xlstm_decode_step(
            params, batch["token"], batch["cache"], batch["pos"], cfg, rules=rules
        )

    return Model(
        cfg=cfg,
        init_px=lambda rng: xlstm.init_xlstm_lm(rng, cfg, _dtype(cfg)),
        forward=fwd,
        decode=dec,
        init_cache=lambda b, s: xlstm.init_xlstm_cache(cfg, b, s, _dtype(cfg)),
        cache_axes=lambda: xlstm.xlstm_cache_axes(cfg),
    )


def _hybrid_family(cfg: ModelConfig) -> Model:
    def fwd(params, batch, rules=None, remat=True, last_only=False):
        return zamba2.forward(params, batch["tokens"], cfg, rules=rules,
                              remat=remat, last_only=last_only)

    def dec(params, batch, rules=None):
        return zamba2.decode_step(
            params, batch["token"], batch["cache"], batch["pos"], cfg, rules=rules
        )

    return Model(
        cfg=cfg,
        init_px=lambda rng: zamba2.init_zamba2(rng, cfg, _dtype(cfg)),
        forward=fwd,
        decode=dec,
        init_cache=lambda b, s: zamba2.init_cache(cfg, b, s, _dtype(cfg)),
        cache_axes=lambda: zamba2.cache_axes(cfg),
    )


def _encdec_family(cfg: ModelConfig) -> Model:
    def fwd(params, batch, rules=None, remat=True, last_only=False):
        del remat  # whisper blocks are cheap enough; remat handled per-block
        enc = whisper.encode(params, batch["frames"].astype(_dtype(cfg)), cfg,
                             rules=rules)
        logits = whisper.decode_train(params, batch["tokens"], enc, cfg,
                                      rules=rules, last_only=last_only)
        return logits, {}

    def dec(params, batch, rules=None):
        return whisper.decode_step(
            params, batch["token"], batch["cache"], batch["pos"], cfg, rules=rules
        )

    return Model(
        cfg=cfg,
        init_px=lambda rng: whisper.init_whisper(rng, cfg, _dtype(cfg)),
        forward=fwd,
        decode=dec,
        init_cache=lambda b, s: whisper.init_cache(cfg, b, s, _dtype(cfg)),
        cache_axes=lambda: whisper.cache_axes(cfg),
    )


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


_FAMILIES = {
    "dense": _dense_family,
    "moe": _dense_family,  # MoE plugs into the transformer block
    "vlm": _vlm_family,
    "xlstm": _xlstm_family,
    "hybrid": _hybrid_family,
    "encdec": _encdec_family,
}


def build_model(cfg: ModelConfig) -> Model:
    return _FAMILIES[cfg.family](cfg)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, zero allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: str | ShapeSpec) -> dict[str, Any]:
    """Inputs of the step lowered for this shape (see DESIGN.md §6)."""
    sp = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32

    if sp.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_len, cfg.d_model),
                                                   jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.family == "vlm":
            batch["img_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.img_tokens, cfg.img_dim), jnp.bfloat16
            )
            batch["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.img_tokens), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return batch

    # decode: one new token against a cache of length s
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }
