# populated by api.py once all families exist
