"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar
memory, true recurrence), alternating 1:1 (xlstm-350m config).

mLSTM stabilization note (DESIGN.md §6): the exponential input gate is
stabilized with a *global* max-shift m_g = max_t ĩ_t computed outside the
scan — exactly the paper's m-state stabilizer with the loosest admissible m,
so the recurrence matches the official form while keeping the chunkwise
parallel structure identical to SSD (decay = cumulative log-sigmoid forget
gates ≤ 0; never overflows). The denominator threshold scales with exp(−m_g)
accordingly.

sLSTM is a genuine sequential recurrence (per-head block-diagonal R); the
input projections for all four gates are hoisted out of the scan so the HLO
cost of the big matmuls is exact (scan-body undercount only affects the
R·h recurrent term — corrected analytically, launch/costs.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, apply_norm, dense_init, init_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_dims(cfg):
    d = cfg.d_model
    di = 2 * d  # pf = 2 up-projection
    h = cfg.n_heads
    p = di // h
    return d, di, h, p


def init_mlstm(key, cfg, dtype=jnp.bfloat16):
    d, di, h, p = mlstm_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "ln": init_norm(ks[0], d, cfg.norm),
        "up_x": Px(dense_init(ks[1], (d, di), 0, dtype), ("embed", "ff")),
        "up_z": Px(dense_init(ks[2], (d, di), 0, dtype), ("embed", "ff")),
        "wq": Px(dense_init(ks[3], (di, di), 0, dtype), ("ff", None)),
        "wk": Px(dense_init(ks[4], (di, di), 0, dtype), ("ff", None)),
        "wv": Px(dense_init(ks[5], (di, di), 0, dtype), ("ff", None)),
        "w_if": Px(dense_init(ks[6], (di, 2 * h), 0, jnp.float32), ("ff", None)),
        "b_if": Px(jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), 3.0 * jnp.ones((h,), jnp.float32)]
        ), (None,)),
        "out_norm": init_norm(ks[7], di, cfg.norm),
        "down": Px(dense_init(ks[8], (di, d), 0, dtype), ("ff", "embed")),
    }


def _mlstm_qkvg(p, u, cfg):
    d, di, h, hp = mlstm_dims(cfg)
    b, s, _ = u.shape
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(b, s, h, hp)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(b, s, h, hp) / jnp.sqrt(
        jnp.float32(hp)
    ).astype(u.dtype)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(b, s, h, hp)
    gates = jnp.einsum("bse,eg->bsg", u.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw, f_raw = gates[..., :h], gates[..., h:]
    return q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), i_raw, f_raw


def mlstm_forward(p, x, cfg, *, rules=None, chunk: int = 256):
    d, di, h, hp = mlstm_dims(cfg)
    b, s, _ = x.shape
    q_len = min(chunk, s)
    nc = s // q_len
    assert s % q_len == 0

    res = x
    xin = apply_norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", xin, p["up_x"])
    z = jnp.einsum("bsd,de->bse", xin, p["up_z"])
    q, k, v, i_raw, f_raw = _mlstm_qkvg(p, u, cfg)

    m_g = jnp.max(i_raw, axis=1, keepdims=True)  # [B,1,H] global stabilizer
    iw = jnp.exp(i_raw - m_g)  # [B,S,H]
    logf = jax.nn.log_sigmoid(f_raw)  # ≤ 0
    lcs_full = jnp.cumsum(logf.reshape(b, nc, q_len, h), axis=2)
    ltot = lcs_full[:, :, -1, :]

    qr = q.reshape(b, nc, q_len, h, hp)
    kr = k.reshape(b, nc, q_len, h, hp)
    vr = v.reshape(b, nc, q_len, h, hp)
    ir = iw.reshape(b, nc, q_len, h)

    xs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qr, kr, vr, ir, lcs_full, ltot)
    )

    def chunk_step(carry, xs_c):
        cst, nst = carry  # C state [B,H,P,P], n state [B,H,P]
        qc, kc, vc, ic, lc, lt = xs_c
        dec = jnp.exp(jnp.clip(lc[:, :, None, :] - lc[:, None, :, :], -60.0, 0.0))
        iota = jnp.arange(q_len)
        causal = (iota[:, None] >= iota[None, :]).astype(jnp.float32)
        wgt = dec * causal[None, :, :, None] * ic[:, None, :, :]  # [B,i,j,H]
        scores = jnp.einsum("bihp,bjhp->bijh", qc, kc)
        num_intra = jnp.einsum("bijh,bjhp->bihp", scores * wgt, vc)
        den_vec = jnp.einsum("bijh,bjhp->bihp", wgt, kc)  # Σ_j dec·i·k_j
        dec_i = jnp.exp(jnp.clip(lc, -60.0, 0.0))
        num_carry = jnp.einsum("bihp,bhpr->bihr", qc, cst) * dec_i[..., None]
        den_carry = jnp.einsum("bihp,bhp->bih", qc, nst) * dec_i
        num = num_intra + num_carry
        den = jnp.sum(qc * den_vec, axis=-1) + den_carry
        dec_j = jnp.exp(jnp.clip(lt[:, None, :] - lc, -60.0, 0.0)) * ic
        c_new = jnp.exp(jnp.clip(lt, -60.0, 0.0))[..., None, None] * cst + jnp.einsum(
            "bjh,bjhp,bjhr->bhpr", dec_j, kc, vc
        )
        n_new = jnp.exp(jnp.clip(lt, -60.0, 0.0))[..., None] * nst + jnp.einsum(
            "bjh,bjhp->bhp", dec_j, kc
        )
        return (c_new, n_new), (num, den)

    c0 = jnp.zeros((b, h, hp, hp), jnp.float32)
    n0 = jnp.zeros((b, h, hp), jnp.float32)
    _, (nums, dens) = jax.lax.scan(chunk_step, (c0, n0), xs)
    num = jnp.moveaxis(nums, 0, 1).reshape(b, s, h, hp)
    den = jnp.moveaxis(dens, 0, 1).reshape(b, s, h)
    thr = jnp.exp(-m_g)  # [B,1,H]
    hout = num / jnp.maximum(jnp.abs(den), thr)[..., None]
    hout = hout.reshape(b, s, di).astype(x.dtype)
    hout = apply_norm(p["out_norm"], hout, cfg.norm, cfg.norm_eps)
    hout = hout * jax.nn.silu(z.astype(jnp.float32)).astype(hout.dtype)
    out = jnp.einsum("bse,ed->bsd", hout, p["down"])
    if rules is not None:
        out = rules.constrain(out, "batch", "seq", "act_embed")
    return res + out


def mlstm_decode(p, x, cfg, state, *, rules=None):
    """state = {"c": [B,H,P,P], "n": [B,H,P], "m": [B,H]} (true m-state)."""
    d, di, h, hp = mlstm_dims(cfg)
    b = x.shape[0]
    res = x
    xin = apply_norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", xin, p["up_x"])
    z = jnp.einsum("bsd,de->bse", xin, p["up_z"])
    q, k, v, i_raw, f_raw = _mlstm_qkvg(p, u, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # [B,H,P]
    i_raw, f_raw = i_raw[:, 0], f_raw[:, 0]  # [B,H]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state["m"], i_raw)
    fw = jnp.exp(jnp.clip(logf + state["m"] - m_new, -60.0, 0.0))
    iw = jnp.exp(jnp.clip(i_raw - m_new, -60.0, 0.0))
    c_new = fw[..., None, None] * state["c"] + iw[..., None, None] * jnp.einsum(
        "bhp,bhr->bhpr", k, v
    )
    n_new = fw[..., None] * state["n"] + iw[..., None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, c_new)
    den = jnp.einsum("bhp,bhp->bh", q, n_new)
    hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    hout = hout.reshape(b, 1, di).astype(x.dtype)
    hout = apply_norm(p["out_norm"], hout, cfg.norm, cfg.norm_eps)
    hout = hout * jax.nn.silu(z.astype(jnp.float32)).astype(hout.dtype)
    out = jnp.einsum("bse,ed->bsd", hout, p["down"])
    return res + out, {"c": c_new, "n": n_new, "m": m_new}


def init_mlstm_state(cfg, batch: int):
    d, di, h, hp = mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, hp, hp), jnp.float32),
        "n": jnp.zeros((batch, h, hp), jnp.float32),
        "m": jnp.full((batch, h), -30.0, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_dims(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    return d, h, d // h


def init_slstm(key, cfg, dtype=jnp.bfloat16):
    d, h, dh = slstm_dims(cfg)
    ks = jax.random.split(key, 8)
    f = int(8 * d / 3 / 64) * 64  # GeGLU pf 4/3 ×2 (xLSTM paper)
    return {
        "ln": init_norm(ks[0], d, cfg.norm),
        "w_in": Px(dense_init(ks[1], (d, 4, h, dh), 0, dtype), ("embed", None, "heads", None)),
        "r": Px(
            (jax.random.normal(ks[2], (4, h, dh, dh), jnp.float32) * (1.0 / jnp.sqrt(dh))),
            (None, "heads", None, None),
        ),
        "b": Px(jnp.zeros((4, h, dh), jnp.float32), (None, "heads", None)),
        "out_norm": init_norm(ks[3], d, cfg.norm),
        "ln_ffn": init_norm(ks[4], d, cfg.norm),
        "ffn_wi": Px(dense_init(ks[5], (d, f), 0, dtype), ("embed", "ff")),
        "ffn_wg": Px(dense_init(ks[6], (d, f), 0, dtype), ("embed", "ff")),
        "ffn_wo": Px(dense_init(ks[7], (f, d), 0, dtype), ("ff", "embed")),
    }


def _slstm_cell(r, gin, st):
    """One step. gin: [B,4,H,dh] pre-activations; st = (c, n, hprev, m)."""
    c, n, hprev, m = st
    rec = jnp.einsum("bhx,ghxy->bghy", hprev, r)  # [B,4,H,dh]
    za, ia, fa, oa = [gin[:, g] + rec[:, g] for g in range(4)]
    z = jnp.tanh(za)
    o = jax.nn.sigmoid(oa)
    m_new = jnp.maximum(fa + m, ia)
    i = jnp.exp(jnp.clip(ia - m_new, -60.0, 0.0))
    f = jnp.exp(jnp.clip(fa + m - m_new, -60.0, 0.0))
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(p, x, cfg, *, rules=None):
    d, h, dh = slstm_dims(cfg)
    b, s, _ = x.shape
    res = x
    xin = apply_norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    gin = (
        jnp.einsum("bsd,dghy->bsghy", xin, p["w_in"]).astype(jnp.float32)
        + p["b"][None, None]
    )  # [B,S,4,H,dh]

    def step(st, g_t):
        st = _slstm_cell(p["r"], g_t, st)
        return st, st[2]

    z0 = jnp.zeros((b, h, dh), jnp.float32)
    st0 = (z0, z0, z0, jnp.full((b, h, dh), -30.0, jnp.float32))
    _, hs = jax.lax.scan(step, st0, jnp.moveaxis(gin, 1, 0))
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    hout = apply_norm(p["out_norm"], hout, cfg.norm, cfg.norm_eps)
    x = res + hout
    # post-block GeGLU FFN (pf 4/3 ×2)
    hf = apply_norm(p["ln_ffn"], x, cfg.norm, cfg.norm_eps)
    a = jnp.einsum("bsd,df->bsf", hf, p["ffn_wi"])
    g = jnp.einsum("bsd,df->bsf", hf, p["ffn_wg"])
    a = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * a
    out = jnp.einsum("bsf,fd->bsd", a, p["ffn_wo"])
    if rules is not None:
        out = rules.constrain(out, "batch", "seq", "act_embed")
    return x + out


def slstm_decode(p, x, cfg, state, *, rules=None):
    d, h, dh = slstm_dims(cfg)
    b = x.shape[0]
    res = x
    xin = apply_norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    gin = (
        jnp.einsum("bsd,dghy->bsghy", xin, p["w_in"]).astype(jnp.float32)
        + p["b"][None, None]
    )[:, 0]
    st = (state["c"], state["n"], state["h"], state["m"])
    st = _slstm_cell(p["r"], gin, st)
    hout = st[2].reshape(b, 1, d).astype(x.dtype)
    hout = apply_norm(p["out_norm"], hout, cfg.norm, cfg.norm_eps)
    x = res + hout
    hf = apply_norm(p["ln_ffn"], x, cfg.norm, cfg.norm_eps)
    a = jnp.einsum("bsd,df->bsf", hf, p["ffn_wi"])
    g = jnp.einsum("bsd,df->bsf", hf, p["ffn_wg"])
    a = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * a
    out = jnp.einsum("bsf,fd->bsd", a, p["ffn_wo"])
    new_state = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
    return x + out, new_state


def init_slstm_state(cfg, batch: int):
    d, h, dh = slstm_dims(cfg)
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -30.0, jnp.float32)}


# ---------------------------------------------------------------------------
# xLSTM language model: alternating mLSTM (even) / sLSTM (odd) blocks
# ---------------------------------------------------------------------------


def is_mlstm(i: int) -> bool:
    return i % 2 == 0


def init_xlstm_lm(key, cfg, dtype=jnp.bfloat16):
    from repro.models.common import embed_init

    keys = jax.random.split(key, cfg.n_layers + 2)
    p = {
        "embed": Px(embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
                    ("vocab", "embed")),
        "ln_f": init_norm(keys[1], cfg.d_model, cfg.norm),
    }
    for i in range(cfg.n_layers):
        init = init_mlstm if is_mlstm(i) else init_slstm
        p[f"layer_{i}"] = init(keys[2 + i], cfg, dtype)
    return p


def xlstm_forward(params, tokens, cfg, *, rules=None, remat: bool = True,
                  last_only: bool = False):
    h = jnp.take(params["embed"], tokens, axis=0)
    if rules is not None:
        h = rules.constrain(h, "batch", "seq", "act_embed")
    import functools

    # close over cfg/rules so jax.checkpoint only ever sees array args
    m_fn = functools.partial(mlstm_forward, cfg=cfg, rules=rules)
    s_fn = functools.partial(slstm_forward, cfg=cfg, rules=rules)
    if remat:
        m_fn, s_fn = jax.checkpoint(m_fn), jax.checkpoint(s_fn)
    for i in range(cfg.n_layers):
        fn = m_fn if is_mlstm(i) else s_fn
        h = fn(params[f"layer_{i}"], h)
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    if rules is not None:
        logits = rules.constrain(logits, "batch", "seq", "vocab")
    return logits, {}


def xlstm_decode_step(params, token, cache, pos, cfg, *, rules=None):
    del pos  # O(1) state — position-free recurrence
    h = jnp.take(params["embed"], token[:, None], axis=0)
    new_cache = {}
    for i in range(cfg.n_layers):
        fn = mlstm_decode if is_mlstm(i) else slstm_decode
        h, st = fn(params[f"layer_{i}"], h, cfg, cache[f"layer_{i}"], rules=rules)
        new_cache[f"layer_{i}"] = st
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    return logits[:, 0], new_cache


def init_xlstm_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    del seq_len, dtype  # constant-size recurrent state (long_500k-native)
    c = {}
    for i in range(cfg.n_layers):
        init = init_mlstm_state if is_mlstm(i) else init_slstm_state
        c[f"layer_{i}"] = init(cfg, batch)
    return c


def xlstm_cache_axes(cfg):
    c = {}
    for i in range(cfg.n_layers):
        if is_mlstm(i):
            c[f"layer_{i}"] = {
                "c": ("batch", "heads", None, None),
                "n": ("batch", "heads", None),
                "m": ("batch", "heads"),
            }
        else:
            c[f"layer_{i}"] = {
                "c": ("batch", "heads", None),
                "n": ("batch", "heads", None),
                "h": ("batch", "heads", None),
                "m": ("batch", "heads", None),
            }
    return c
