"""Zamba2 hybrid: 81 Mamba2 blocks + one *shared* attention block applied
every ``attn_every`` blocks (weights shared across sites; each site keeps its
own KV cache when decoding). The shared block is a full GQA transformer
block (attention + gated MLP) as in Zamba2's shared transformer layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mamba2, transformer
from repro.models.common import Px, apply_norm, embed_init, init_norm


def attn_sites(cfg) -> list[int]:
    period = max(cfg.attn_every, 1)
    return [i for i in range(cfg.n_layers) if (i + 1) % period == 0]


def init_zamba2(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.n_layers + 3)
    p = {
        "embed": Px(embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
                    ("vocab", "embed")),
        "ln_f": init_norm(keys[1], cfg.d_model, cfg.norm),
        "shared": transformer.init_block(keys[2], cfg, dtype),
    }
    for i in range(cfg.n_layers):
        p[f"ssm_{i}"] = mamba2.init_mamba2(keys[3 + i], cfg, dtype)
    return p


def forward(params, tokens, cfg, *, rules=None, remat: bool = True,
            last_only: bool = False):
    h = jnp.take(params["embed"], tokens, axis=0)
    if rules is not None:
        h = rules.constrain(h, "batch", "seq", "act_embed")
    sites = set(attn_sites(cfg))
    import functools

    # close over cfg/rules so jax.checkpoint only ever sees array args
    ssm_fn = functools.partial(mamba2.ssd_forward, cfg=cfg, rules=rules)
    blk_fn = functools.partial(transformer.apply_block, cfg=cfg, rules=rules)
    if remat:
        ssm_fn = jax.checkpoint(ssm_fn)
        blk_fn = jax.checkpoint(blk_fn)
    for i in range(cfg.n_layers):
        h = ssm_fn(params[f"ssm_{i}"], h)
        if i in sites:
            h, _ = blk_fn(params["shared"], h)
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    if rules is not None:
        logits = rules.constrain(logits, "batch", "seq", "vocab")
    return logits, {}


def decode_step(params, token, cache, pos, cfg, *, rules=None):
    h = jnp.take(params["embed"], token[:, None], axis=0)
    sites = set(attn_sites(cfg))
    new_cache = {}
    for i in range(cfg.n_layers):
        h, st = mamba2.ssd_decode(params[f"ssm_{i}"], h, cfg,
                                  cache[f"ssm_{i}"], rules=rules)
        new_cache[f"ssm_{i}"] = st
        if i in sites:
            h, kv = transformer.apply_block_decode(
                params["shared"], h, cfg, cache[f"attn_{i}"], pos, rules=rules
            )
            new_cache[f"attn_{i}"] = kv
    h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    return logits[:, 0], new_cache


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    c = {}
    for i in range(cfg.n_layers):
        c[f"ssm_{i}"] = mamba2.init_ssm_state(cfg, batch, dtype)
    for i in attn_sites(cfg):
        c[f"attn_{i}"] = {
            "k": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    return c


def cache_axes(cfg):
    axes = {}
    for i in range(cfg.n_layers):
        axes[f"ssm_{i}"] = mamba2.ssm_state_axes(cfg)
    for i in attn_sites(cfg):
        axes[f"attn_{i}"] = {
            "k": ("batch", "kvseq", "kv_heads", None),
            "v": ("batch", "kvseq", "kv_heads", None),
        }
    return axes
