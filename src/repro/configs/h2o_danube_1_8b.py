"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]. SWA(4096) → sub-quadratic → long_500k RUNS."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    swa_window=4096,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, swa_window=8, dtype="float32",
    )
