"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000
— GeGLU, head_dim=256 [arXiv:2403.08295]. Full attention → long_500k skipped
(DESIGN.md §6)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, head_dim=32, dtype="float32",
    )
