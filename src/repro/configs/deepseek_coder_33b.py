"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama arch [arXiv:2401.14196]. Full attention → long_500k
skipped."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=512, dtype="float32",
    )
