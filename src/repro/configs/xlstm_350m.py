"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks, alternating 1:1 [arXiv:2405.04517; unverified]. d_ff=0: projections
live inside the xLSTM blocks (mLSTM pf=2, sLSTM GeGLU pf=4/3·2)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab=512,
        dtype="float32",
    )
