"""The paper's own workloads: SSumM graph-summarization configs.

Small/mid datasets run for real (synthetic Table-2 stand-ins); the web-scale
rows are dry-run-only shapes proving the distributed pipeline fits a
512-chip mesh (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses

from repro.core.types import SummaryConfig
from repro.graphs.synthetic import DATASETS


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    dataset: str
    k_frac: float = 0.3
    cfg: SummaryConfig = SummaryConfig()
    dry_run_only: bool = False

    @property
    def v(self) -> int:
        return DATASETS[self.dataset].v

    @property
    def e(self) -> int:
        return DATASETS[self.dataset].e_target


WORKLOADS: dict[str, GraphWorkload] = {
    name: GraphWorkload(
        dataset=name,
        dry_run_only=name in ("web-uk-02", "web-uk-05", "livejournal", "skitter"),
    )
    for name in DATASETS
}

# benchmark defaults (paper Sect. 4.1: targets 10%–60% of Size(G), T=20)
TARGET_FRACS = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6]
DEFAULT_T = 20
