"""whisper-large-v3 [audio]: enc-dec, 32 enc + 32 dec layers, d_model=1280,
20H, d_ff=5120, vocab=51866 [arXiv:2212.04356]. The conv/mel frontend is a
STUB: input_specs() provides precomputed frame embeddings [B, 1500, 1280].
Full attention → long_500k skipped."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    enc_layers=32,
    enc_len=1500,
    norm="layernorm",
    act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, enc_len=12, dtype="float32",
    )
