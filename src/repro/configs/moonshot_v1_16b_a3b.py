"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B]."""

import dataclasses

from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    moe=MoESpec(num_experts=64, top_k=6),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
        vocab=512, moe=MoESpec(num_experts=4, top_k=2), dtype="float32",
    )
