"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].
The assignment line says 40e top-8 (source comment says 32e) — we follow the
spec line. 40 experts are padded to 48 for the 16-way EP axis (router masks
the 8 dead experts)."""

import dataclasses

from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoESpec(num_experts=40, top_k=8, padded_experts=48),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=512, moe=MoESpec(num_experts=5, top_k=2, padded_experts=6),
        dtype="float32",
    )
