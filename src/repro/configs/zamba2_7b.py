"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block every 6
[arXiv:2411.15242]. Sub-quadratic (SSM) → long_500k RUNS (attention sites
keep full KV caches — 13 sites)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, ssm_state=8, ssm_head_dim=16, attn_every=2, dtype="float32",
    )
