"""Config registry: ``get_config(name)`` / ``get_smoke_config(name)``.

One module per assigned architecture (exact numbers from the assignment
table) plus the paper's own graph workloads (``ssumm_paper``).
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoESpec,
    RunConfig,
    SHAPES,
    ShapeSpec,
    applicable_shapes,
)

ARCHS = [
    "xlstm_350m",
    "granite_moe_3b_a800m",
    "moonshot_v1_16b_a3b",
    "gemma_7b",
    "deepseek_coder_33b",
    "qwen2_5_14b",
    "h2o_danube_1_8b",
    "zamba2_7b",
    "whisper_large_v3",
    "paligemma_3b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update(
    {
        "xlstm-350m": "xlstm_350m",
        "granite-moe-3b-a800m": "granite_moe_3b_a800m",
        "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
        "gemma-7b": "gemma_7b",
        "deepseek-coder-33b": "deepseek_coder_33b",
        "qwen2.5-14b": "qwen2_5_14b",
        "h2o-danube-1.8b": "h2o_danube_1_8b",
        "zamba2-7b": "zamba2_7b",
        "whisper-large-v3": "whisper_large_v3",
        "paligemma-3b": "paligemma_3b",
    }
)


def _module(name: str):
    mod = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
