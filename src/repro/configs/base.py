"""Config schema: architectures, input shapes, mesh and run settings.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
(exact numbers from the assignment table) plus a ``smoke()`` reduction of the
same family for CPU tests. Input shapes are the four assigned LM shapes;
applicability is derived from the architecture family (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # experts padded up to a multiple of the EP axis when needed (router
    # masks the padding with -inf); see granite config.
    padded_experts: int | None = None
    # dispatch implementation: "gspmd" (sort-based dispatch, sharding left
    # to GSPMD — the baseline) or "a2a" (shard_map with explicit all_to_all
    # expert parallelism — §Perf iteration 1, see models/moe.py)
    impl: str = "gspmd"

    def experts_padded(self, ep: int = 16) -> int:
        if self.padded_experts is not None:
            return self.padded_experts
        return -(-self.num_experts // ep) * ep


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # override when != d_model // n_heads
    act: str = "silu"  # silu (swiglu) | gelu (geglu)
    qkv_bias: bool = False
    swa_window: int | None = None  # sliding-window attention
    moe: MoESpec | None = None
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0  # hybrid: shared attn block every N ssm blocks
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_len: int = 0
    # vlm (paligemma)
    img_tokens: int = 0
    img_dim: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    dtype: str = "bfloat16"

    # ---- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §6 skip table)."""
        return self.family in ("xlstm", "hybrid") or self.swa_window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline terms)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, h, k = self.hd, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (h * hd) + 2 * d * (k * hd) + (h * hd) * d
        if self.act in ("silu", "gelu"):
            mlp_dense = 3 * d * f  # gated
        else:
            mlp_dense = 2 * d * f
        total = emb
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn + mlp_dense + 2 * d)
        elif self.family == "moe":
            e = self.moe.num_experts
            total += self.n_layers * (attn + e * mlp_dense + 2 * d)
        elif self.family == "xlstm":
            # alternating mLSTM / sLSTM blocks, pf=2 up/down projections
            m_blk = 2 * d * (2 * d) + 3 * (2 * d) * self.hd_x + 2 * d
            s_blk = 4 * d * d + 4 * d * d // max(self.n_heads, 1) + 3 * d * d
            total += (self.n_layers // 2) * (m_blk + s_blk) + self.n_layers * 2 * d
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            m_blk = d * (2 * di + 2 * self.ssm_state) + di * d + 3 * di
            n_attn = self.n_layers // max(self.attn_every, 1)
            total += self.n_layers * m_blk + (attn + mlp_dense)  # shared attn
            del n_attn
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + mlp_dense + 4 * d)
            dec = self.n_layers * (2 * attn + mlp_dense + 6 * d)
            total += enc + dec
        return int(total)

    @property
    def hd_x(self) -> int:
        return (2 * self.d_model) // max(self.n_heads, 1)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_dense = 3 * d * f
        e, k = self.moe.num_experts, self.moe.top_k
        return int(self.param_count() - self.n_layers * (e - k) * mlp_dense)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The assigned shape set, minus documented skips (DESIGN.md §6)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Trainer/server settings shared across drivers."""

    lr: float = 3e-4
    lr_min_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 = no gradient accumulation
    remat: bool = True
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    grad_compress: str = "none"  # none | topk | int8
    topk_ratio: float = 0.05
    seed: int = 0
