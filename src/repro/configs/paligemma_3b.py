"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216
— SigLIP frontend (STUB: input_specs() provides patch embeddings
[B, 256, 1152]) + gemma backbone [arXiv:2407.07726]. Full attention →
long_500k skipped."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    act="gelu",
    img_tokens=256,
    img_dim=1152,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab=512, img_tokens=8, img_dim=48, dtype="float32",
    )
