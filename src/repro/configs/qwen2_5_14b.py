"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA + QKV bias [hf:Qwen/Qwen2.5]. Full attention → long_500k
skipped."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, dtype="float32",
    )
