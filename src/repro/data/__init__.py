from repro.data.loader import Loader
from repro.data.tokens import SyntheticTokens, TokenDatasetConfig

__all__ = ["Loader", "SyntheticTokens", "TokenDatasetConfig"]
