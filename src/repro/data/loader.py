"""Prefetching host→device loader.

One background thread keeps ``prefetch`` batches ahead of the training loop
(generation + device_put overlap the previous step's compute). The iterator
is index-based and restartable: ``Loader(ds, start_index=s)`` resumes the
exact stream after a checkpoint restore.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable

import jax


class Loader:
    def __init__(
        self,
        batch_fn: Callable[[int], Any],
        *,
        start_index: int = 0,
        prefetch: int = 2,
        put_fn: Callable[[Any], Any] | None = None,
    ):
        self._batch_fn = batch_fn
        self._put = put_fn or jax.device_put
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._index = start_index
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        i = self._index
        while not self._stop.is_set():
            try:
                batch = self._put(self._batch_fn(i))
            except BaseException as e:
                self._q.put(e)
                return
            self._q.put((i, batch))
            i += 1

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, BaseException):
            raise item
        return item  # (index, device_batch)

    def close(self) -> None:
        self._stop.set()
        # drain so the worker's blocking put releases
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
