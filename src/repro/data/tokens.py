"""Deterministic synthetic LM token pipeline.

The container is offline, so text corpora are synthesized from a seeded
order-1 Markov "language" with a Zipf unigram marginal — structured enough
that a causal LM shows a real, monotone loss drop (the quickstart trains on
it), cheap enough to generate on the fly at any batch size.

Determinism contract (fault tolerance): batch ``i`` is a pure function of
``(seed, i)`` — restarting from a checkpoint at step ``s`` regenerates the
exact stream by continuing at ``i = s``, with no pipeline state to persist.
Sharding contract (elasticity): ``batch_for_rank`` slices the same global
batch by data-parallel rank, so any mesh width reproduces identical global
batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDatasetConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # unigram skew
    markov_states: int = 64  # order-1 structure strength


class SyntheticTokens:
    """Stateless batch generator: ``batch(i) -> int32 [B, S]``."""

    def __init__(self, cfg: TokenDatasetConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        m = min(cfg.markov_states, cfg.vocab)
        # sparse-support transition table over m frequent states; each state
        # prefers a few successors (gives the LM learnable structure)
        probs = rng.dirichlet(np.full(8, 0.4), size=m)
        succ = np.stack([rng.choice(m, size=8, replace=False) for _ in range(m)])
        self._succ = succ.astype(np.int64)  # [m, 8]
        self._cum = np.cumsum(probs, axis=1)  # [m, 8]
        # Zipf-ish map from the m states to the full vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self._vocab_cum = np.cumsum(w / w.sum())
        self._state_token = rng.permutation(cfg.vocab)[:m]
        self._m = m

    def batch(self, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed + 1) * 1_000_003 + index)
        b, s = cfg.global_batch, cfg.seq_len
        state = rng.integers(0, self._m, size=b)
        out = np.empty((b, s), dtype=np.int64)
        u = rng.random((b, s))
        noise = rng.random((b, s)) < 0.1  # 10% unigram noise tokens
        noise_tok = np.searchsorted(self._vocab_cum, rng.random((b, s)))
        for t in range(s):
            pick = (u[:, t, None] <= self._cum[state]).argmax(axis=1)
            state = self._succ[state, pick]
            out[:, t] = self._state_token[state]
        out = np.where(noise, noise_tok, out)
        return out.astype(np.int32)

    def batch_for_rank(self, index: int, rank: int, dp: int) -> np.ndarray:
        """This rank's slice of global batch ``index`` (elastic-safe)."""
        g = self.batch(index)
        per = g.shape[0] // dp
        return g[rank * per : (rank + 1) * per]
