"""Jitted public wrappers for the Pallas kernels with oracle fallback.

``use_pallas=False`` routes to the pure-jnp oracle in :mod:`repro.kernels.ref`
(used on CPU hosts and in differential tests). ``interpret=True`` executes
the Pallas kernel body in Python — the container-level validation mode; set
False on real TPUs.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.entropy_bits import pair_cost_pallas
from repro.kernels.merge_gain import merge_gain_pallas


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def merge_gain(
    m, n, s, t, n_u, cidx, w, cbar, log2v, *, use_pallas=True, interpret=True
):
    """(rel, red) gain matrices [G, C, C] — Eq. (20)/(17) per candidate pair."""
    if use_pallas:
        return merge_gain_pallas(
            m, n, s, t, n_u, cidx, w, cbar, log2v, interpret=interpret
        )
    return ref.merge_gain_ref(m, n, s, t, n_u, cidx, w, cbar, log2v)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pair_cost(cnt, pi, cbar, log2v, *, use_pallas=True, interpret=True):
    """Optimal per-pair description cost min(C̄+Cost₍₁₎, Cost₍₂₎)."""
    if use_pallas:
        return pair_cost_pallas(cnt, pi, cbar, log2v, interpret=interpret)
    return ref.pair_cost_ref(cnt, pi, cbar, log2v)
