"""Kernel-dispatch registry: one seam for every merge-gain / pair-cost call.

Backends (``KERNEL_BACKENDS``):

  * ``"ref"``              — the jitted pure-jnp oracle (:mod:`repro.kernels.ref`);
    the XLA path a CPU host runs, and the differential-test baseline.
  * ``"pallas-interpret"`` — the Pallas kernel body executed in Python
    (``interpret=True``); the container-level validation mode exercised by
    the CI lane (slow: a host callback per grid step).
  * ``"pallas"``           — the compiled Pallas kernel; the deployment path
    on real TPUs (VMEM sizing notes in :mod:`repro.kernels.merge_gain`).

Selection (:func:`resolve_kernel_backend`): an explicit name — from
``SummaryConfig.kernel_backend`` — beats the ``SSUMM_KERNEL`` environment
variable, which beats the default ``"ref"``. Unknown names raise with the
valid set. The resolved name is a jit-static argument, so each backend
compiles its own executable and the choice never leaks into traced code.

Compat shim: :func:`backend_from_flags` maps the retired ``use_pallas`` /
``interpret`` bool pair onto a registry name for any caller still speaking
the old vocabulary; nothing inside the repo threads those bools anymore.
"""

from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.entropy_bits import pair_cost_pallas
from repro.kernels.merge_gain import merge_gain_pallas

ENV_VAR = "SSUMM_KERNEL"

# name → (merge_gain impl, pair_cost impl); the single dispatch table.
_REGISTRY = {
    "ref": (
        ref.merge_gain_ref,
        ref.pair_cost_ref,
    ),
    "pallas-interpret": (
        functools.partial(merge_gain_pallas, interpret=True),
        functools.partial(pair_cost_pallas, interpret=True),
    ),
    "pallas": (
        functools.partial(merge_gain_pallas, interpret=False),
        functools.partial(pair_cost_pallas, interpret=False),
    ),
}

KERNEL_BACKENDS = tuple(sorted(_REGISTRY))


def resolve_kernel_backend(name: str | None = None) -> str:
    """Resolve a backend name: explicit config > ``$SSUMM_KERNEL`` > "ref".

    Raises ``ValueError`` naming the valid set for unknown backends (both
    from the argument and from the environment).
    """
    source = "config"
    if name is None:
        name = os.environ.get(ENV_VAR) or "ref"
        source = f"${ENV_VAR}"
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown kernel backend {name!r} (from {source}); "
            f"valid backends: {list(KERNEL_BACKENDS)}"
        )
    return name


def backend_from_flags(use_pallas: bool, interpret: bool = True) -> str:
    """Compat shim: the retired ``use_pallas``/``interpret`` bool pair →
    registry name. New code should pass backend names directly."""
    if not use_pallas:
        return "ref"
    return "pallas-interpret" if interpret else "pallas"


@functools.partial(jax.jit, static_argnames=("backend",))
def merge_gain(m, n, s, t, n_u, cidx, w, cbar, log2v, *, backend=None):
    """(rel, red) gain matrices [G, C, C] — Eq. (20)/(17) per candidate pair."""
    impl, _ = _REGISTRY[resolve_kernel_backend(backend)]
    return impl(m, n, s, t, n_u, cidx, w, cbar, log2v)


@functools.partial(jax.jit, static_argnames=("backend",))
def pair_cost(cnt, pi, cbar, log2v, *, backend=None):
    """Optimal per-pair description cost min(C̄+Cost₍₁₎, Cost₍₂₎)."""
    _, impl = _REGISTRY[resolve_kernel_backend(backend)]
    return impl(cnt, pi, cbar, log2v)
