"""Pallas TPU kernel: fused per-pair optimal description cost (Eq. 11/12).

Elementwise but transcendental-heavy (two log2 per element + select); fusing
the entropy + explicit-bits min into one VMEM pass avoids three HBM round
trips in the evaluation path that runs over the full pair table (length |E|)
every iteration. Tiled 1-D over 8·128-aligned blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128


def _pair_cost_kernel(scal_ref, cnt_ref, pi_ref, out_ref):
    cbar = scal_ref[0]
    log2v = scal_ref[1]
    cnt = cnt_ref[...]
    pi = pi_ref[...]
    safe_pi = jnp.maximum(pi, 1.0)
    sigma = jnp.clip(cnt / safe_pi, 0.0, 1.0)
    xlogx = jnp.where(sigma > 0.0, sigma * jnp.log2(jnp.maximum(sigma, 1e-38)), 0.0)
    ylogy = jnp.where(
        sigma < 1.0, (1.0 - sigma) * jnp.log2(jnp.maximum(1.0 - sigma, 1e-38)), 0.0
    )
    ent = jnp.where((pi > 0.0) & (cnt > 0.0) & (cnt < pi), -pi * (xlogx + ylogy), 0.0)
    out = jnp.where(cnt > 0.0, jnp.minimum(cbar + ent, 2.0 * cnt * log2v), 0.0)
    out_ref[...] = out


def pair_cost_pallas(
    cnt: jax.Array, pi: jax.Array, cbar: jax.Array, log2v: jax.Array,
    *, interpret: bool = True,
) -> jax.Array:
    """1-D tiled fused pair cost; pads to a BLOCK multiple internally."""
    (e,) = cnt.shape
    pad = (-e) % BLOCK
    cnt_p = jnp.pad(cnt.astype(jnp.float32), (0, pad))
    pi_p = jnp.pad(pi.astype(jnp.float32), (0, pad))
    scal = jnp.stack([cbar.astype(jnp.float32), log2v.astype(jnp.float32)])
    n_blocks = (e + pad) // BLOCK
    out = pl.pallas_call(
        _pair_cost_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e + pad,), jnp.float32),
        interpret=interpret,
    )(scal, cnt_p, pi_p)
    return out[:e]
