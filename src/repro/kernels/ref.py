"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: small, obviously-right, fully
vectorized implementations used by tests (``assert_allclose`` sweeps) and as
the CPU path behind the ``"ref"`` registry backend (kernels/ops.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def entropy_bits_ref(cnt: jnp.ndarray, pi: jnp.ndarray) -> jnp.ndarray:
    """`-|Π|(σlog₂σ+(1-σ)log₂(1-σ))` with 0·log0 := 0 (Eq. 9 sans C̄)."""
    pi = pi.astype(jnp.float32)
    cnt = cnt.astype(jnp.float32)
    sigma = jnp.clip(cnt / jnp.maximum(pi, 1.0), 0.0, 1.0)
    xlogx = jnp.where(sigma > 0.0, sigma * jnp.log2(jnp.maximum(sigma, 1e-38)), 0.0)
    ylogy = jnp.where(
        sigma < 1.0, (1.0 - sigma) * jnp.log2(jnp.maximum(1.0 - sigma, 1e-38)), 0.0
    )
    return jnp.where((pi > 0.0) & (cnt > 0.0) & (cnt < pi), -pi * (xlogx + ylogy), 0.0)


def pair_cost_ref(
    cnt: jnp.ndarray, pi: jnp.ndarray, cbar: jnp.ndarray, log2v: jnp.ndarray
) -> jnp.ndarray:
    """min(C̄ + Cost₍₁₎, Cost₍₂₎) per pair (Eq. 11/12)."""
    c1 = cbar + entropy_bits_ref(cnt, pi)
    c2 = 2.0 * cnt.astype(jnp.float32) * log2v
    return jnp.where(cnt > 0.0, jnp.minimum(c1, c2), 0.0)


def merge_gain_ref(
    m: jnp.ndarray,  # f32[G, C, U]
    n: jnp.ndarray,  # f32[G, C]
    s: jnp.ndarray,  # f32[G, C]
    t: jnp.ndarray,  # f32[G, C]
    n_u: jnp.ndarray,  # f32[G, U]
    cidx: jnp.ndarray,  # i32[G, C]
    w: jnp.ndarray,  # f32[G, C, C]
    cbar: jnp.ndarray,  # f32 scalar
    log2v: jnp.ndarray,  # f32 scalar
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense (G,C,C,U) evaluation of Relative_Reduction (Eq. 20) / Reduction
    (Eq. 17). Returns ``(rel, red)`` with -inf/-0 on invalid entries."""
    g, c, u = m.shape

    def f(cnt, pi):
        return pair_cost_ref(cnt, pi, cbar, log2v)

    # per-member exact-tail bookkeeping
    pi_row = n[..., None] * n_u[:, None, :]  # [G,C,U]
    row_cost = jnp.sum(f(m, pi_row), axis=-1)  # [G,C]
    self_cost = f(s, n * (n - 1.0) * 0.5)
    tail = jnp.maximum(t - row_cost - self_cost, 0.0)

    onehot = (
        jnp.arange(u, dtype=jnp.int32)[None, None, :] == cidx[..., None]
    ).astype(jnp.float32)  # [G,C,U]

    merged_cnt = m[:, :, None, :] + m[:, None, :, :]  # [G,C,C,U]
    npair = n[:, :, None] + n[:, None, :]  # [G,C,C]
    pi_m = npair[..., None] * n_u[:, None, None, :]
    fv = f(merged_cnt, pi_m)
    mask = 1.0 - onehot[:, :, None, :] - onehot[:, None, :, :]
    cross = jnp.sum(fv * mask, axis=-1)  # [G,C,C]

    s_m = s[:, :, None] + s[:, None, :] + w
    self_m = f(s_m, npair * (npair - 1.0) * 0.5)
    merged = cross + self_m + tail[:, :, None] + tail[:, None, :]

    denom = t[:, :, None] + t[:, None, :] - f(w, n[:, :, None] * n[:, None, :])
    red = denom - merged

    eye = jnp.eye(c, dtype=bool)[None]
    valid = (n[:, :, None] > 0) & (n[:, None, :] > 0) & ~eye & (denom > 1e-6)
    rel = jnp.where(valid, 1.0 - merged / jnp.maximum(denom, 1e-6), -jnp.inf)
    red = jnp.where(valid, red, 0.0)
    return rel, red
