"""Pallas TPU kernel: pairwise merge-gain matrices for candidate groups.

This is the compute hot spot of SSumM (DESIGN.md §5): per outer iteration it
evaluates ``O(Σ_g C²·U)`` fused entropy-cost terms. The kernel processes one
candidate group per grid step, keeping that group's union-space tables in
VMEM:

    VMEM working set  ≈ (C·U [m] + C·U [merged] + C·U [mask] + 3·C·C) · 4 B
    defaults C=64, U=256 → ≈ 0.25 MB  (≪ 16 MB VMEM/core)

Last dims are multiples of 128 so elementwise math vectorizes onto the VPU
lanes; the arithmetic is branch-free (`where` selects), so the body maps to
a dense VPU pipeline. The per-pair loop is a ``fori_loop`` over rows ``i``
with a full ``(C, U)`` vector body — C² scalar iterations are never emitted.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _f_cost(cnt, pi, cbar, log2v):
    """min(C̄ + entropy bits, explicit bits) — branch-free (Eq. 11/12)."""
    pi_f = pi.astype(jnp.float32)
    safe_pi = jnp.maximum(pi_f, 1.0)
    sigma = jnp.clip(cnt / safe_pi, 0.0, 1.0)
    xlogx = jnp.where(sigma > 0.0, sigma * jnp.log2(jnp.maximum(sigma, 1e-38)), 0.0)
    ylogy = jnp.where(
        sigma < 1.0, (1.0 - sigma) * jnp.log2(jnp.maximum(1.0 - sigma, 1e-38)), 0.0
    )
    ent = jnp.where(
        (pi_f > 0.0) & (cnt > 0.0) & (cnt < pi_f), -pi_f * (xlogx + ylogy), 0.0
    )
    c1 = cbar + ent
    c2 = 2.0 * cnt * log2v
    return jnp.where(cnt > 0.0, jnp.minimum(c1, c2), 0.0)


def _merge_gain_kernel(
    scal_ref,  # f32[2]            (cbar, log2v)
    m_ref,  # f32[1, C, U]
    n_ref,  # f32[1, C]
    s_ref,  # f32[1, C]
    t_ref,  # f32[1, C]
    nu_ref,  # f32[1, U]
    cidx_ref,  # i32[1, C]
    w_ref,  # f32[1, C, C]
    rel_ref,  # f32[1, C, C] out
    red_ref,  # f32[1, C, C] out
):
    cbar = scal_ref[0]
    log2v = scal_ref[1]
    m = m_ref[0]  # (C, U)
    n = n_ref[0]  # (C,)
    s = s_ref[0]
    t = t_ref[0]
    nu = nu_ref[0]  # (U,)
    cidx = cidx_ref[0]  # (C,)
    w = w_ref[0]  # (C, C)
    c = m.shape[0]
    u = m.shape[1]

    f = functools.partial(_f_cost, cbar=cbar, log2v=log2v)

    # exact-tail bookkeeping (held in registers/VMEM for the whole group)
    pi_row = n[:, None] * nu[None, :]
    row_cost = jnp.sum(f(m, pi_row), axis=-1)
    self_cost = f(s, n * (n - 1.0) * 0.5)
    tail = jnp.maximum(t - row_cost - self_cost, 0.0)

    cols = jax.lax.broadcasted_iota(jnp.int32, (c, u), 1)
    onehot = (cols == cidx[:, None]).astype(jnp.float32)  # (C, U)
    jidx = jax.lax.iota(jnp.int32, c)

    def per_row(i, _):
        mi = jax.lax.dynamic_slice_in_dim(m, i, 1, axis=0)  # (1, U)
        ohi = jax.lax.dynamic_slice_in_dim(onehot, i, 1, axis=0)  # (1, U)
        ni = jax.lax.dynamic_slice_in_dim(n, i, 1)[0]
        si = jax.lax.dynamic_slice_in_dim(s, i, 1)[0]
        ti = jax.lax.dynamic_slice_in_dim(t, i, 1)[0]
        tli = jax.lax.dynamic_slice_in_dim(tail, i, 1)[0]
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=0)[0]  # (C,)

        merged_cnt = m + mi  # (C, U)
        npair = n + ni  # (C,)
        pi_m = npair[:, None] * nu[None, :]
        fv = f(merged_cnt, pi_m)
        mask = 1.0 - onehot - ohi
        cross = jnp.sum(fv * mask, axis=-1)  # (C,)

        self_m = f(s + si + wi, npair * (npair - 1.0) * 0.5)
        merged = cross + self_m + tail + tli
        denom = t + ti - f(wi, n * ni)
        red_i = denom - merged
        valid = (n > 0.0) & (ni > 0.0) & (jidx != i) & (denom > 1e-6)
        rel_i = jnp.where(valid, 1.0 - merged / jnp.maximum(denom, 1e-6), -jnp.inf)
        red_i = jnp.where(valid, red_i, 0.0)
        rel_ref[0, pl.dslice(i, 1), :] = rel_i[None, :]
        red_ref[0, pl.dslice(i, 1), :] = red_i[None, :]
        return 0

    jax.lax.fori_loop(0, c, per_row, 0)


def merge_gain_pallas(
    m: jax.Array,  # f32[G, C, U]
    n: jax.Array,
    s: jax.Array,
    t: jax.Array,
    n_u: jax.Array,
    cidx: jax.Array,
    w: jax.Array,
    cbar: jax.Array,
    log2v: jax.Array,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Grid over groups; one group's tables per program, VMEM resident."""
    g, c, u = m.shape
    scal = jnp.stack([cbar.astype(jnp.float32), log2v.astype(jnp.float32)])
    grid = (g,)
    specs = [
        pl.BlockSpec((2,), lambda i: (0,)),  # scalars, replicated
        pl.BlockSpec((1, c, u), lambda i: (i, 0, 0)),  # m
        pl.BlockSpec((1, c), lambda i: (i, 0)),  # n
        pl.BlockSpec((1, c), lambda i: (i, 0)),  # s
        pl.BlockSpec((1, c), lambda i: (i, 0)),  # t
        pl.BlockSpec((1, u), lambda i: (i, 0)),  # n_u
        pl.BlockSpec((1, c), lambda i: (i, 0)),  # cidx
        pl.BlockSpec((1, c, c), lambda i: (i, 0, 0)),  # w
    ]
    out_specs = [
        pl.BlockSpec((1, c, c), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, c, c), lambda i: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((g, c, c), jnp.float32),
        jax.ShapeDtypeStruct((g, c, c), jnp.float32),
    ]
    fn = pl.pallas_call(
        _merge_gain_kernel,
        grid=grid,
        in_specs=specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    rel, red = fn(scal, m, n, s, t, n_u, cidx, w)
    return rel, red
