from repro.utils.segments import (  # noqa: F401
    boundaries_from_keys,
    cummax,
    rank_in_segment,
    segment_ids_from_boundaries,
    segment_start,
)
