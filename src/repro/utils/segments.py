"""Segment helpers for sorted-key dataflow (the TPU-native workhorse of repro.core).

Everything here operates on *sorted* key arrays with fixed shapes and is
jit-compatible. These primitives replace the hash-map bookkeeping of the
reference CPU implementation of SSumM with sort/scan dataflow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cummax(x: jax.Array, axis: int = 0) -> jax.Array:
    """Inclusive cumulative maximum along ``axis``."""
    return jax.lax.cummax(x, axis=axis)


def segment_start(is_new: jax.Array) -> jax.Array:
    """Index of the start of each element's segment.

    ``is_new[i]`` is True when element ``i`` opens a new segment (element 0
    must be True). Returns ``start[i]`` = index of the first element of the
    segment containing ``i``.
    """
    n = is_new.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return cummax(jnp.where(is_new, idx, 0))


def rank_in_segment(is_new: jax.Array) -> jax.Array:
    """0-based rank of each element within its segment (sorted layout)."""
    n = is_new.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return idx - segment_start(is_new)


def boundaries_from_keys(*keys: jax.Array) -> jax.Array:
    """``is_new`` flags for a lexicographically sorted multi-key array."""
    ks = keys[0]
    n = ks.shape[0]
    new = jnp.zeros((n,), dtype=bool).at[0].set(True)
    for k in keys:
        prev = jnp.concatenate([k[:1], k[:-1]])
        new = new | (k != prev)
    return new


def segment_ids_from_boundaries(is_new: jax.Array) -> jax.Array:
    """Contiguous segment ids (0-based) from ``is_new`` flags."""
    return jnp.cumsum(is_new.astype(jnp.int32)) - 1
