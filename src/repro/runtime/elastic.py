"""Elastic re-meshing + preemption handling.

On a node failure the job restarts with fewer devices. ``plan_mesh`` picks
the best (pod, data, model) factorization for the survivor count, keeping
the model axis as close as possible to the original TP degree (params must
still fit) and folding everything else into data parallelism. The global
batch is preserved by scaling per-device batch (gradient accumulation picks
up any remainder — see dist/microbatch.py).

``PreemptionGuard`` turns SIGTERM/SIGINT into a cooperative "save and exit"
flag that the train loop polls once per step — the checkpoint manager's
atomic commit makes the save safe even if the grace period expires. A
*second* signal means the grace period is over: the handler hard-exits
immediately (``os._exit``) with the conventional ``128 + signum`` status,
leaving at worst an ignored ``.tmp-`` directory behind.

Drivers that saved a committed checkpoint before exiting raise
:class:`Preempted` and exit with :data:`RESUMABLE_EXIT` (BSD
``EX_TEMPFAIL``) — a nonzero status that supervisors can distinguish from
a crash: rerun the same command with ``--resume``.
"""

from __future__ import annotations

import dataclasses
import os
import signal

import numpy as np

from repro.dist.compat import make_mesh

#: exit status of a run that checkpointed and stopped on SIGTERM/SIGINT —
#: nonzero (the work is unfinished) but *resumable* (EX_TEMPFAIL).
RESUMABLE_EXIT = 75


class Preempted(RuntimeError):
    """Raised at a host-sync point after a committed save-on-signal.

    ``step`` is the checkpoint step the run is resumable from.
    """

    def __init__(self, step: int):
        super().__init__(f"preempted; resumable from checkpoint step {step}")
        self.step = step


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    per_device_batch: int
    accum_steps: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def plan_mesh(
    n_devices: int,
    *,
    global_batch: int,
    want_model: int = 16,
    want_pods: int = 1,
) -> MeshPlan:
    """Largest usable mesh for ``n_devices`` survivors.

    Picks model-axis size = the largest divisor of ``n_devices`` that is
    ≤ ``want_model`` (never grows TP beyond the tuned degree), then the pod
    axis, then data soaks up the rest. Per-device batch follows from the
    preserved global batch; if data-parallel width doesn't divide the global
    batch, gradient accumulation supplies the remainder.
    """
    model = max(d for d in _divisors(n_devices) if d <= want_model)
    rest = n_devices // model
    pods = max(d for d in _divisors(rest) if d <= want_pods)
    data = rest // pods
    if pods > 1:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    dp = pods * data
    if global_batch % dp == 0:
        per_dev, accum = global_batch // dp, 1
    elif global_batch < dp:
        # fewer examples than DP shards (e.g. the summarize driver's
        # batch-free plan): one per device, no accumulation
        per_dev, accum = 1, 1
    else:
        # smallest accumulation count that makes microbatches divide evenly
        accum = next(a for a in range(2, global_batch + 1)
                     if global_batch % (dp * a) == 0 or dp * a >= global_batch)
        per_dev = max(global_batch // (dp * accum), 1)
    return MeshPlan(shape=shape, axes=axes, per_device_batch=per_dev,
                    accum_steps=accum)


def make_mesh_from_plan(plan: MeshPlan):
    return make_mesh(plan.shape, plan.axes)


class PreemptionGuard:
    """Cooperative SIGTERM/SIGINT → checkpoint-and-exit flag.

    First signal: set :attr:`preempted`; the loop observes it at its next
    host-sync point, saves, and exits :data:`RESUMABLE_EXIT`. Second
    signal (the sender insists): hard-exit *from the handler* with
    ``hard_exit_code`` (default ``128 + signum``, the shell convention for
    death-by-signal) — no save is attempted, the previous commit is the
    resume point, and any half-written ``.tmp-`` directory is ignored on
    restore and garbage-collected by the next save.
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT),
                 hard_exit_code: int | None = None):
        self._requested = False
        self._count = 0
        self._hard_exit_code = hard_exit_code
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._count += 1
        if self._count >= 2:
            code = self._hard_exit_code
            os._exit(128 + signum if code is None else code)
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    @property
    def signal_count(self) -> int:
        return self._count

    def restore(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)
