from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import (
    RESUMABLE_EXIT,
    MeshPlan,
    Preempted,
    PreemptionGuard,
    make_mesh_from_plan,
    plan_mesh,
)
from repro.runtime.straggler import StragglerEvent, StragglerMonitor

__all__ = [
    "CheckpointManager",
    "MeshPlan",
    "Preempted",
    "PreemptionGuard",
    "RESUMABLE_EXIT",
    "make_mesh_from_plan",
    "plan_mesh",
    "StragglerEvent",
    "StragglerMonitor",
]
