"""Straggler detection: EMA step-time monitor with outlier actions.

At thousand-chip scale a single slow host (thermal throttle, failing HBM,
noisy neighbor) sets the pace of every synchronous collective. The monitor
keeps an exponential moving average + variance of the step time and flags
steps that exceed ``mean + z·std`` (and a hard ratio). Consumers register
callbacks: log, checkpoint-and-remesh (drop the slow host via elastic
restart), or re-layout.

The detector is deliberately host-side and out of the jit path — it
measures the only thing that matters (wall time between optimizer commits)
and costs nothing on-device.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    mean: float
    std: float
    ratio: float


class StragglerMonitor:
    def __init__(
        self,
        *,
        ema_decay: float = 0.95,
        z_threshold: float = 4.0,
        ratio_threshold: float = 2.0,
        warmup_steps: int = 5,
    ):
        self.decay = ema_decay
        self.z = z_threshold
        self.ratio = ratio_threshold
        self.warmup = warmup_steps
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.events: list[StragglerEvent] = []
        self._callbacks: list[Callable[[StragglerEvent], None]] = []
        self._last: float | None = None

    def on_straggler(self, fn: Callable[[StragglerEvent], None]) -> None:
        self._callbacks.append(fn)

    def begin_step(self) -> None:
        self._last = time.perf_counter()

    def end_step(self, step: int) -> float:
        assert self._last is not None, "begin_step not called"
        dt = time.perf_counter() - self._last
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step time; returns True if flagged as a straggler step."""
        self.count += 1
        if self.count <= self.warmup:
            # seed the statistics without flagging
            self.mean = dt if self.count == 1 else (
                self.decay * self.mean + (1 - self.decay) * dt
            )
            return False
        std = max(self.var, 1e-12) ** 0.5
        is_slow = (dt > self.mean + self.z * std) and (
            dt > self.ratio * max(self.mean, 1e-9)
        )
        if is_slow:
            ev = StragglerEvent(
                step=step, step_time=dt, mean=self.mean, std=std,
                ratio=dt / max(self.mean, 1e-9),
            )
            self.events.append(ev)
            for fn in self._callbacks:
                fn(ev)
        else:
            # straggler steps are excluded from the EMA so one hiccup does
            # not mask a second one
            delta = dt - self.mean
            self.mean += (1 - self.decay) * delta
            self.var = self.decay * (self.var + (1 - self.decay) * delta * delta)
        return is_slow
