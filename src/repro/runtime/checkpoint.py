"""Sharded, atomic, async checkpointing with reshard-on-restore.

Design (DESIGN.md §7 fault tolerance):

  * **Layout**: one directory per step, one ``.npy`` blob per pytree leaf
    (keyed by its flattened path) plus a ``manifest.json`` holding the tree
    structure, dtypes, shapes, logical axes, and the step metadata. Blobs
    are written per *host-local shard* on multi-host systems — here the
    process owns every device, so blobs are full arrays; the manifest format
    carries the shard grid so the layout extends to per-host blobs without a
    format change.
  * **Atomicity**: everything is written into ``<dir>/.tmp-<step>`` and
    ``os.replace``-d to ``<dir>/step_<n>`` only after an fsync'd ``COMMIT``
    marker is in place. A crash mid-write leaves only a ``.tmp-`` directory,
    which restore ignores and the next save garbage-collects.
  * **Async**: ``save_async`` snapshots device arrays to host memory
    synchronously (cheap: device_get of sharded arrays) and hands the
    serialization + fsync to a single background writer thread — the train
    loop resumes immediately (1-step decoupling). ``wait()`` joins the
    in-flight write; saves are serialized to keep the keep-N GC simple.
  * **Reshard-on-restore**: blobs are loaded as host numpy and
    ``jax.device_put`` with the *target* sharding — restoring onto any mesh
    shape (elastic restarts after losing a pod) needs no resharding pass.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

COMMIT = "COMMIT"


def _path_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out) if out else "_root"


def _flatten(tree) -> dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_key(path): leaf for path, leaf in leaves}


class CheckpointManager:
    """keep-N checkpoint directory manager with an async writer thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._err: list[BaseException] = []
        # per-step accounting: {"snapshot_wall_s", "write_wall_s", "bytes"}
        # — snapshot time is what the driver loop actually pays for an
        # async save; write time and bytes happen off-thread
        self.save_stats: dict[int, dict] = {}
        self._thread = threading.Thread(target=self._writer, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None) -> None:
        """Synchronous save (snapshot + write + commit on caller thread)."""
        snap = self._snapshot_timed(step, tree)
        self._write(step, snap, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        """Snapshot now, serialize in the background (1-step decoupled)."""
        self._raise_pending()
        snap = self._snapshot_timed(step, tree)
        self._q.put((step, snap, extra or {}))

    def wait(self) -> None:
        """Block until every queued async save has committed."""
        self._q.join()
        self._raise_pending()

    def _snapshot(self, tree) -> dict[str, np.ndarray]:
        flat = _flatten(tree)
        # one device_get per leaf; sharded arrays gather to host here
        return {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _snapshot_timed(self, step: int, tree) -> dict[str, np.ndarray]:
        t0 = time.perf_counter()
        snap = self._snapshot(tree)
        self.save_stats[step] = {
            "snapshot_wall_s": time.perf_counter() - t0,
            "write_wall_s": None,
            "bytes": None,
        }
        return snap

    def _writer(self) -> None:
        while True:
            step, snap, extra = self._q.get()
            try:
                self._write(step, snap, extra)
            except BaseException as e:  # surfaced on next save/wait
                self._err.append(e)
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._err:
            raise self._err.pop(0)

    def _write(self, step: int, snap: dict[str, np.ndarray], extra: dict) -> None:
        t0 = time.perf_counter()
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in snap.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        st = self.save_stats.setdefault(step, {"snapshot_wall_s": None})
        st["write_wall_s"] = time.perf_counter() - t0
        st["bytes"] = self.step_bytes(step)
        self._gc()

    def step_bytes(self, step: int) -> int:
        """On-disk size of a committed step (0 if absent/uncommitted)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        if not os.path.exists(os.path.join(d, COMMIT)):
            return 0
        return sum(os.path.getsize(os.path.join(d, f))
                   for f in os.listdir(d))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and os.path.exists(os.path.join(full, COMMIT)):
                out.append(int(name[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template,
        step: int | None = None,
        sharding_fn: Callable[[str], Any] | None = None,
    ):
        """Restore into the structure of ``template``.

        ``sharding_fn(path_key)`` returns the *target* sharding per leaf —
        pass shardings derived from the (possibly different) current mesh to
        get reshard-on-restore. Returns ``(tree, step, extra)``.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat_t = _flatten(template)
        restored: dict[str, Any] = {}
        for key, leaf in flat_t.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {d} missing leaf {key!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            want = jax.tree.leaves(leaf)
            if want and hasattr(want[0], "shape") and tuple(arr.shape) != tuple(
                want[0].shape
            ):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                    f"template {want[0].shape}"
                )
            if sharding_fn is not None:
                restored[key] = jax.device_put(arr, sharding_fn(key))
            else:
                restored[key] = jax.device_put(arr)

        # rebuild the tree in template order
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = [restored[_path_key(p)] for p, _ in paths]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["step"], manifest.get("extra", {})
